//! The serving coordinator: request types, the cache-backed inference
//! engine (paper Alg. 2 on the hot path), cross-request continuous
//! batching, and a thread-pool server. Pure std — no async runtime exists
//! in the offline vendor set, and a thread-per-worker loop over an mpsc
//! queue is exactly the right shape at this scale.
//!
//! # Continuous batching
//!
//! Workers no longer pull one request and run it end-to-end: the admission
//! queue ([`super::batcher::Batcher`]) groups in-flight requests into batch
//! windows (knobs: `RESMOE_BATCH` / `RESMOE_LINGER_US`), and
//! [`Engine::handle_batch`] executes a whole window through ONE transformer
//! forward — token rows of all prefill-shaped requests (Score/Classify)
//! concatenated, routing run once per layer, and each expert's combined
//! rows dispatched through a single fused forward before outputs scatter
//! back per request. The per-layer center term (`SharedAct`) is computed
//! once for every concurrent client, and each expert materializes at most
//! once per window.
//!
//! **Bit-for-bit parity (prefill)**: a batched window of prefill-shaped
//! requests produces responses byte-identical to serving the same
//! requests one-at-a-time, under every cache budget. Two ingredients:
//! every per-row kernel (norms, routing, expert matmuls, combine,
//! lm_head) is row-independent, and the cache replays per-request serve
//! decisions in serial (request-major) order against per-block-
//! partitioned state (see `cache.rs`), so the decision sequence each
//! block sees is literally the serial one.
//! `tests/prop_batching.rs` pins the property across request mixes,
//! methods, rates, budgets, and both engine modes. One caveat: the
//! guarantee is about the *request-driven* serve sequence, so it requires
//! async prefetch disabled (or quiesced) — prefetch mutates LRU stamps
//! and shard residency on background-timing grounds that no serial
//! reference can reproduce, batched or not ([`Engine::disable_prefetch`]
//! is the determinism knob; the parity tests use it on both sides).
//!
//! # Decode batching (relaxed parity)
//!
//! Since PR 10, runs of consecutive Generate requests decode TOGETHER:
//! an iteration-level scheduler ([`super::batcher::DecodeScheduler`])
//! feeds one layer-major forward per step over every active sequence
//! ([`Model::decode_step_batch_hooked`]), admitting later sequences into
//! the running batch as earlier ones retire. Each sequence reserves its
//! worst-case KV footprint from a shared page pool before joining
//! ([`crate::moe::KvPagePool`]); a refused lease falls back to the solo
//! path — reservations are never revoked from a live sequence.
//!
//! Decode batching carries a RELAXED parity contract, not the prefill
//! theorem: per-row kernels are still bit-identical, but interleaving
//! sequences step-major changes the ORDER the stateful cost model sees
//! serves in (and the whole window amortizes `RESTORE_AMORTIZE_TOKENS`),
//! so a slot can be answered fused where the serial reference restored,
//! and logits then differ at float-summation-order magnitude. What holds
//! instead, pinned by `tests/prop_decode.rs`: greedy token sequences
//! equal the sequential reference under roomy budgets (decisions
//! coincide ⇒ bit parity), per-token logits stay within a tight relative
//! error under thrashing budgets, and the decision-metric conservation
//! laws survive every schedule. `RESMOE_DECODE_BATCH=1` (or
//! [`Engine::set_decode_batch`]) disables the lane and restores the
//! pre-PR-10 serial semantics exactly.
//!
//! Error semantics under batching match serial serving: a store or
//! integrity failure mid-window is pinned on the requests whose rows
//! routed to the failing expert (each answers `Response::Error` with the
//! same message serial serving would produce), and every other request in
//! the window still gets its answer. When the failing expert's block has
//! a resident barycenter center, the cache degrades the serve instead of
//! failing it and the affected responses come back wrapped in
//! [`Response::Degraded`] — approximate, never silent.
//!
//! # Observability
//!
//! Every engine carries a lock-free [`Registry`] (shared with its cache):
//! `server.*` latency/throughput instruments, `batch.*` window counters,
//! and the cache's `cache.*` set, all snapshotable at any time through
//! [`Engine::metrics_snapshot`] or an in-band [`Request::Metrics`]
//! request. With `RESMOE_TRACE` set, each request additionally emits a
//! JSONL stage trace (queue wait, forward, per-block route/serve/
//! materialize spans — see `obs::trace`). Tracing on or off, responses
//! and counter sequences are bit-for-bit identical: observation never
//! feeds back into serving decisions.

use super::batcher::{
    next_window, BatchPolicy, Batcher, DecodePolicy, DecodeScheduler, FlushReason,
};
use super::cache::{CacheMetrics, ExpertCache, Serve};
use super::metrics::{
    BatchCounters, BatchMetrics, DecodeCounters, DecodeMetrics, ServerMetrics, ServerStats,
};
use crate::compress::{center_shared_act, fused_forward_expert, CompressedLayer, SharedAct};
use crate::moe::{
    combine_slot_output, gather_rows, group_parts, kv_lease_bytes, route_dispatch_combine,
    route_groups, Ffn, FfnHook, KvCache, KvLease, KvPagePool, Model,
};
use crate::obs::{trace, MetricsSnapshot, Registry};
use crate::store::{ExpertStore, Prefetcher};
use crate::tensor::{kernel_label, Matrix};
use crate::util::stats::logsumexp;
use anyhow::Result;
use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

// The ExpertCache is internally synchronized (short metadata critical
// sections + per-key singleflight; see cache.rs module docs), so the engine
// shares it as a plain `Arc` — N workers overlap their store fetches,
// decodes, and restore matmuls instead of serializing on one cache mutex.

#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Max requests per batch window.
    pub batch_max: usize,
    /// Max linger (µs) before a partial window flushes.
    pub batch_wait_us: u64,
    /// Byte budget for the restored-expert cache.
    pub cache_budget_bytes: usize,
    pub workers: usize,
    /// Admission control: max requests queued or executing before
    /// [`Server::submit`] sheds with [`Response::Overloaded`]. 0 (the
    /// default) = unbounded, bit-identical to the pre-admission server.
    pub max_queue: usize,
    /// Per-request deadline (ms): a job still waiting for a worker past
    /// its deadline is shed with [`Response::Overloaded`] instead of
    /// executing doomed work. 0 (the default) = no deadline.
    pub deadline_ms: u64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            batch_max: 8,
            batch_wait_us: 500,
            cache_budget_bytes: 64 * 1024 * 1024,
            workers: 2,
            max_queue: 0,
            deadline_ms: 0,
        }
    }
}

impl ServerConfig {
    /// Defaults with the `RESMOE_BATCH` / `RESMOE_LINGER_US` window knobs
    /// plus the `RESMOE_MAX_QUEUE` / `RESMOE_DEADLINE_MS` admission knobs
    /// applied.
    pub fn from_env() -> ServerConfig {
        Self::from_lookup(|name| std::env::var(name).ok())
    }

    /// [`ServerConfig::from_env`] with the variable source injected (the
    /// same injectable-lookup test pattern as [`BatchPolicy::from_lookup`]).
    ///
    /// All four knobs share the [`crate::util::env`] parser semantics:
    /// unset/garbage → default, overflow-wide digit strings saturate to
    /// `u64::MAX` (pre-fix, `"99…9"` failed `parse()` and silently meant
    /// *unbounded* for `RESMOE_MAX_QUEUE` — the opposite of what the
    /// operator asked for), and the `usize` narrowing saturates on 32-bit
    /// targets. Documented zero semantics: `RESMOE_MAX_QUEUE=0` =
    /// unbounded queue, `RESMOE_DEADLINE_MS=0` = no deadline (both are the
    /// defaults), `RESMOE_BATCH=0` clamps to 1.
    pub fn from_lookup(lookup: impl Fn(&str) -> Option<String>) -> ServerConfig {
        let p = BatchPolicy::from_lookup(&lookup);
        let d = ServerConfig::default();
        ServerConfig {
            batch_max: p.max_batch,
            batch_wait_us: p.linger_us,
            max_queue: crate::util::env::knob_usize(&lookup, "RESMOE_MAX_QUEUE", d.max_queue),
            deadline_ms: crate::util::env::knob_u64(&lookup, "RESMOE_DEADLINE_MS", d.deadline_ms),
            ..d
        }
    }
}

/// Inference requests.
#[derive(Debug, Clone)]
pub enum Request {
    /// Mean next-token log-prob of a sequence (scoring / PPL serving).
    Score { tokens: Vec<u32> },
    /// Greedy generation.
    Generate { prompt: Vec<u32>, max_new: usize },
    /// Classification through a stored task head.
    Classify { task: String, tokens: Vec<u32> },
    /// In-band metrics exposition: answers with the Prometheus-style
    /// snapshot of the engine's registry, without touching the model.
    Metrics,
}

impl Request {
    pub fn token_count(&self) -> u64 {
        match self {
            Request::Score { tokens } => tokens.len() as u64,
            Request::Generate { prompt, max_new } => (prompt.len() + max_new) as u64,
            Request::Classify { tokens, .. } => tokens.len() as u64,
            Request::Metrics => 0,
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Score(f64),
    Generate(Vec<u32>),
    Classify(usize),
    /// Prometheus-style exposition text (see `obs::MetricsSnapshot`).
    Metrics(String),
    Error(String),
    /// A successful answer computed with at least one barycenter-degraded
    /// expert serve ([`Serve::Degraded`]): numerically approximate (the
    /// paper's rate→0 limit), never silent — clients unwrap explicitly.
    Degraded(Box<Response>),
    /// Shed by admission control (queue full) or a missed deadline; the
    /// request was NOT executed.
    Overloaded(String),
}

impl Response {
    /// The exact answer, or the degraded approximation unwrapped — for
    /// clients that prefer approximate output over handling the marker.
    pub fn into_inner(self) -> Response {
        match self {
            Response::Degraded(inner) => *inner,
            other => other,
        }
    }
}

/// How a request executes inside a batch window.
enum Shape {
    /// One transformer forward over the token rows — batchable across
    /// requests (Score/Classify).
    Prefill,
    /// Token-by-token decode (Generate) — joins the window's batched
    /// decode run, or runs alone when decode batching is disabled
    /// (Metrics is Sequential too and always answers solo).
    Sequential,
    /// Fails validation; answered without touching the engine.
    Invalid(String),
}

/// The cache-backed engine: holds the backbone with compressed MoE blocks
/// *stripped of their dense experts* (only routers + shared experts stay
/// resident) plus the compressed representations and the restore cache.
/// In artifact mode ([`Engine::from_store`]) even the residuals live on
/// disk: the cache demand-pages individual expert shards and an async
/// prefetcher decodes router-predicted shards ahead of time.
#[derive(Clone)]
pub struct Engine {
    model: Arc<Model>,
    cache: Option<Arc<ExpertCache>>,
    prefetcher: Option<Arc<Prefetcher>>,
    /// block → next compressed block (the prefetch prediction target).
    next_block: Arc<HashMap<usize, usize>>,
    /// Metrics registry: the cache's (so `cache.*`, `batch.*`, and
    /// `server.*` instruments share one snapshot) or standalone for dense
    /// engines.
    obs: Arc<Registry>,
    /// Continuous-batching counters (lock-free, shared across clones).
    batch: Arc<BatchCounters>,
    /// Decode-lane counters (`decode.*`) — registered unconditionally so
    /// every tenant snapshot exports the same instrument schema.
    decode: Arc<DecodeCounters>,
    /// KV page pool decode sequences lease from: the cache's (one extra
    /// per-block share of the cache budget) or an effectively-unbounded
    /// pool for dense engines.
    kv_pool: Arc<KvPagePool>,
    /// Max sequences per batched decode step (`RESMOE_DECODE_BATCH`);
    /// <= 1 disables decode batching — every Generate runs through the
    /// sequential reference path, restoring pre-PR-10 bit-for-bit window
    /// parity (the configuration `prop_batching` pins).
    decode_max: usize,
    /// Optional tenant tag (multi-tenant deployments: several engines over
    /// one shared store). Tags exported snapshots; no serving behavior.
    tenant: Option<Arc<str>>,
}

impl Engine {
    /// Plain engine over a dense model (no compression).
    pub fn dense(model: Model) -> Engine {
        let obs = Arc::new(Registry::new());
        let batch = Arc::new(BatchCounters::new(&obs));
        let decode = Arc::new(DecodeCounters::new(&obs));
        Engine {
            model: Arc::new(model),
            cache: None,
            prefetcher: None,
            next_block: Arc::new(HashMap::new()),
            obs,
            batch,
            decode,
            // No cache budget to charge KV against — cap far below the
            // `cur + bytes` overflow line but above any real demand.
            kv_pool: Arc::new(KvPagePool::new(usize::MAX / 2)),
            decode_max: DecodePolicy::from_env().max_batch,
            tenant: None,
        }
    }

    /// Engine over compressed layers with a restore cache. `model` is the
    /// ORIGINAL (or restored) model; its compressed blocks are stripped.
    pub fn compressed(
        model: Model,
        layers: Vec<(usize, CompressedLayer)>,
        cache_budget_bytes: usize,
    ) -> Engine {
        let blocks: Vec<usize> = layers.iter().map(|(b, _)| *b).collect();
        let stripped = model.strip_experts(&blocks);
        let cache = Arc::new(ExpertCache::new(layers, cache_budget_bytes));
        let obs = cache.registry().clone();
        let batch = Arc::new(BatchCounters::new(&obs));
        let decode = Arc::new(DecodeCounters::new(&obs));
        let kv_pool = cache.kv_pool().clone();
        Engine {
            model: Arc::new(stripped),
            cache: Some(cache),
            prefetcher: None,
            next_block: Arc::new(HashMap::new()),
            obs,
            batch,
            decode,
            kv_pool,
            decode_max: DecodePolicy::from_env().max_batch,
            tenant: None,
        }
    }

    /// Construct-from-artifact: open an `RMES` store, load only the
    /// expert-stripped backbone + per-layer skeletons, and serve with
    /// demand-paged residual shards plus async prefetch. No full-file
    /// decompression happens here or later on the serving path.
    pub fn from_store(artifact: &Path, cache_budget_bytes: usize) -> Result<Engine> {
        let store = Arc::new(ExpertStore::open(artifact)?);
        Self::from_shared_store(store, cache_budget_bytes)
    }

    /// [`Engine::from_store`] over an ALREADY-OPEN store handle. Several
    /// engines built this way share one artifact (one file handle, one
    /// read-bytes ledger) while keeping fully independent caches, budgets,
    /// and metrics registries — the multi-tenant contention setup the
    /// traffic harness exercises: tenants compete for store bandwidth but
    /// can never evict each other's residents.
    pub fn from_shared_store(
        store: Arc<ExpertStore>,
        cache_budget_bytes: usize,
    ) -> Result<Engine> {
        let model = store.load_backbone()?;
        let cache = Arc::new(ExpertCache::from_store(store.clone(), cache_budget_bytes)?);
        let blocks = store.blocks();
        let mut next_block = HashMap::new();
        for w in blocks.windows(2) {
            next_block.insert(w[0], w[1]);
        }
        let prefetcher = Arc::new(Prefetcher::new(cache.clone(), store));
        let obs = cache.registry().clone();
        let batch = Arc::new(BatchCounters::new(&obs));
        let decode = Arc::new(DecodeCounters::new(&obs));
        let kv_pool = cache.kv_pool().clone();
        Ok(Engine {
            model: Arc::new(model),
            cache: Some(cache),
            prefetcher: Some(prefetcher),
            next_block: Arc::new(next_block),
            obs,
            batch,
            decode,
            kv_pool,
            decode_max: DecodePolicy::from_env().max_batch,
            tenant: None,
        })
    }

    /// Tag this engine handle (and its clones made afterwards) with a
    /// tenant name; exported snapshots carry the tag.
    pub fn set_tenant(&mut self, name: &str) {
        self.tenant = Some(Arc::from(name));
    }

    pub fn tenant(&self) -> Option<&str> {
        self.tenant.as_deref()
    }

    /// Disable async prefetch on THIS engine handle (clones made earlier
    /// keep theirs) — determinism knob for tests and A/B benches.
    pub fn disable_prefetch(&mut self) {
        self.prefetcher = None;
        self.next_block = Arc::new(HashMap::new());
    }

    /// Block until in-flight prefetches land (deterministic metric reads).
    pub fn quiesce_prefetch(&self) {
        if let Some(pf) = &self.prefetcher {
            pf.quiesce();
        }
    }

    /// The backing artifact store, in [`Engine::from_store`] mode.
    pub fn backing_store(&self) -> Option<Arc<ExpertStore>> {
        self.cache.as_ref()?.backing_store().cloned()
    }

    pub fn model(&self) -> &Model {
        &self.model
    }

    pub fn cache_metrics(&self) -> Option<CacheMetrics> {
        self.cache.as_ref().map(|c| c.metrics())
    }

    /// The engine's metrics registry (`cache.*` + `batch.*` + whatever the
    /// server registers on top). Shared by every clone of this engine.
    pub fn registry(&self) -> &Arc<Registry> {
        &self.obs
    }

    /// Point-in-time snapshot of every registered instrument — lock-free
    /// with respect to serving (see [`Registry::snapshot`]). Carries the
    /// engine's tenant tag when one is set.
    pub fn metrics_snapshot(&self) -> MetricsSnapshot {
        let mut snap = self.obs.snapshot();
        snap.tenant = self.tenant.as_ref().map(|t| t.to_string());
        snap
    }

    /// Cumulative per-slot serve counts from the cache (empty for dense
    /// engines) — see [`ExpertCache::slot_serves`].
    pub fn slot_serves(&self) -> Vec<(usize, usize, u64)> {
        self.cache.as_ref().map(|c| c.slot_serves()).unwrap_or_default()
    }

    /// Snapshot of the continuous-batching counters (see
    /// [`super::metrics::batch_summary`]).
    pub fn batch_metrics(&self) -> BatchMetrics {
        self.batch.snapshot()
    }

    /// Snapshot of the decode-lane counters (see
    /// [`super::metrics::decode_summary`]).
    pub fn decode_metrics(&self) -> DecodeMetrics {
        self.decode.snapshot()
    }

    /// Set the max sequences per batched decode step on THIS engine handle
    /// (clones made earlier keep theirs). `n <= 1` disables decode
    /// batching entirely: every Generate runs the sequential reference
    /// path and windows regain pre-PR-10 bit-for-bit parity — the
    /// determinism knob `prop_batching` uses, mirroring
    /// [`Engine::disable_prefetch`].
    pub fn set_decode_batch(&mut self, n: usize) {
        self.decode_max = n.max(1);
    }

    /// The KV page pool decode sequences lease from (the cache's pool, or
    /// a dense engine's unbounded stand-in).
    pub fn kv_pool(&self) -> &Arc<KvPagePool> {
        &self.kv_pool
    }

    /// Record a flushed window's reason + linger wait on the batch
    /// counters. `pub(crate)` so the loadgen harness can attribute its
    /// virtual windows the same way the live server worker does.
    pub(crate) fn note_flush(&self, reason: FlushReason, waited_us: u64) {
        self.batch.record_flush(reason, waited_us);
    }

    /// Toggle the restore-free fused serve path (on by default; benches
    /// compare against the restore-only policy by switching it off).
    pub fn set_fused(&self, enabled: bool) {
        if let Some(c) = &self.cache {
            c.set_fused_enabled(enabled);
        }
    }

    pub fn resident_expert_bytes(&self) -> Option<(usize, usize)> {
        self.cache.as_ref().map(|c| (c.compressed_bytes(), c.used_bytes()))
    }

    /// (always-resident compressed bytes, restored dense bytes, paged shard
    /// bytes) — the three-way memory story of a store-backed deployment.
    pub fn resident_breakdown(&self) -> Option<(usize, usize, usize)> {
        self.cache
            .as_ref()
            .map(|c| (c.compressed_bytes(), c.used_bytes(), c.paged_bytes()))
    }

    fn hook(&self) -> EngineHook<'_> {
        EngineHook {
            model: &self.model,
            cache: self.cache.as_deref(),
            prefetcher: self.prefetcher.as_deref(),
            next_block: &self.next_block,
            batch: &self.batch,
        }
    }

    /// Trace-line `kind` tag for a request.
    fn req_kind(req: &Request) -> &'static str {
        match req {
            Request::Score { .. } => "score",
            Request::Generate { .. } => "generate",
            Request::Classify { .. } => "classify",
            Request::Metrics => "metrics",
        }
    }

    fn shape(&self, req: &Request) -> Shape {
        match req {
            Request::Score { tokens } => {
                if tokens.len() < 2 || tokens.len() > self.model.cfg.max_seq {
                    Shape::Invalid("score: need 2..=max_seq tokens".into())
                } else {
                    Shape::Prefill
                }
            }
            Request::Generate { prompt, .. } => {
                if prompt.is_empty() || prompt.len() >= self.model.cfg.max_seq {
                    Shape::Invalid("generate: bad prompt length".into())
                } else {
                    Shape::Sequential
                }
            }
            Request::Classify { task, tokens } => {
                if self.model.head(task).is_none() {
                    Shape::Invalid(format!("no head for task '{task}'"))
                } else if tokens.is_empty() || tokens.len() > self.model.cfg.max_seq {
                    Shape::Invalid("classify: need 1..=max_seq tokens".into())
                } else {
                    Shape::Prefill
                }
            }
            // Answered from the registry alone; runs at its admission
            // position like any sequential request (flushing a pending
            // prefill run keeps the response ordering intuitive).
            Request::Metrics => Shape::Sequential,
        }
    }

    pub fn handle(&self, req: &Request) -> Response {
        // Install a trace for this request unless one is already active on
        // this thread (a sequential request inside `handle_batch` joins the
        // window's trace; its spans land on the window's line).
        let owns_trace = trace::begin();
        let resp = self.handle_inner(req);
        if owns_trace {
            if let Some((wall, spans)) = trace::finish() {
                trace::emit_request(
                    trace::next_request_id(),
                    Self::req_kind(req),
                    kernel_label(),
                    0,
                    wall,
                    &spans,
                );
            }
        }
        resp
    }

    fn handle_inner(&self, req: &Request) -> Response {
        // Discard any stale fault attribution (e.g. from a predecessor
        // that panicked between noting a fault and draining it).
        let _ = take_forward_faults();
        let resp = self.handle_dispatch(req);
        let faults = take_forward_faults();
        if let Some((_, msg)) = faults.errors.into_iter().next() {
            return Response::Error(msg);
        }
        if !faults.degraded.is_empty() && !matches!(resp, Response::Error(_)) {
            return Response::Degraded(Box::new(resp));
        }
        resp
    }

    fn handle_dispatch(&self, req: &Request) -> Response {
        match req {
            Request::Score { tokens } => {
                if let Shape::Invalid(msg) = self.shape(req) {
                    return Response::Error(msg);
                }
                let hook = self.hook();
                let h = {
                    let _s = trace::span("forward");
                    self.model.hidden_states_hooked(tokens, None, &hook)
                };
                let _s = trace::span("head");
                let logits = h.matmul_nt(&self.model.lm_head);
                let mut total = 0.0f64;
                for i in 0..tokens.len() - 1 {
                    let row = logits.row(i);
                    total += (row[tokens[i + 1] as usize] - logsumexp(row)) as f64;
                }
                Response::Score(total / (tokens.len() - 1) as f64)
            }
            Request::Generate { prompt, max_new } => {
                if let Shape::Invalid(msg) = self.shape(req) {
                    return Response::Error(msg);
                }
                // One span over prompt ingestion + the whole decode loop
                // (per-token spans would dominate the trace).
                let _s = trace::span("decode");
                let hook = self.hook();
                let mut caches = self.model.fresh_caches();
                let mut logits = vec![0.0f32; self.model.cfg.vocab_size];
                for &t in prompt {
                    logits = self.model.decode_step_hooked(t, &mut caches, &hook);
                }
                let mut out = Vec::new();
                for _ in 0..*max_new {
                    if caches[0].len >= self.model.cfg.max_seq {
                        break;
                    }
                    let next = logits
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .map(|(i, _)| i as u32)
                        .unwrap();
                    out.push(next);
                    logits = self.model.decode_step_hooked(next, &mut caches, &hook);
                }
                Response::Generate(out)
            }
            Request::Classify { task, tokens } => {
                if let Shape::Invalid(msg) = self.shape(req) {
                    return Response::Error(msg);
                }
                let head = self.model.head(task).expect("validated").clone();
                let hook = self.hook();
                let h = {
                    let _s = trace::span("forward");
                    self.model.hidden_states_hooked(tokens, None, &hook)
                };
                let _s = trace::span("head");
                let logits = head.matvec(h.row(h.rows - 1));
                let pred = logits
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap();
                Response::Classify(pred)
            }
            Request::Metrics => Response::Metrics(self.obs.snapshot().to_prometheus()),
        }
    }

    /// Execute one batch window. Consecutive prefill-shaped requests
    /// (Score/Classify) share one concatenated transformer forward with
    /// responses **byte-identical** to calling [`Engine::handle`] on each
    /// in order; consecutive Generate requests share one batched decode
    /// loop under the relaxed parity contract (module docs); invalid
    /// requests answer immediately and — since they never touch the
    /// cache — split neither kind of run.
    pub fn handle_batch(&self, reqs: &[Request]) -> Vec<Response> {
        self.handle_batch_traced(reqs, None)
    }

    /// [`Engine::handle_batch`] plus per-request admission waits from the
    /// server's batcher: with tracing on, every member request gets its own
    /// JSONL line — its `queue.wait` prepended to the window's shared
    /// execution spans (the work that produced a batched response IS the
    /// window's work). With tracing off this is exactly `handle_batch`.
    pub fn handle_batch_traced(
        &self,
        reqs: &[Request],
        queue_waits_ns: Option<&[u64]>,
    ) -> Vec<Response> {
        let owns_trace = trace::begin();
        let out = self.handle_batch_inner(reqs);
        if owns_trace {
            if let Some((wall, spans)) = trace::finish() {
                for (i, req) in reqs.iter().enumerate() {
                    let q = queue_waits_ns.map_or(0, |w| w[i]);
                    trace::emit_request(
                        trace::next_request_id(),
                        Self::req_kind(req),
                        kernel_label(),
                        q,
                        wall + q,
                        &spans,
                    );
                }
            }
        }
        out
    }

    fn handle_batch_inner(&self, reqs: &[Request]) -> Vec<Response> {
        if !reqs.is_empty() {
            self.batch.record_window(reqs.len());
        }
        let mut out: Vec<Option<Response>> = vec![None; reqs.len()];
        // Two run accumulators: consecutive prefill-shaped requests share
        // one concatenated forward, consecutive Generates share one
        // batched decode loop. A request of the other shape (or a
        // non-Generate sequential request like Metrics) flushes the
        // opposing run, so both runs execute at their first member's
        // admission position and responses keep window order.
        let mut prefill: Vec<usize> = Vec::new();
        let mut decode: Vec<usize> = Vec::new();
        for i in 0..=reqs.len() {
            let shape = (i < reqs.len()).then(|| self.shape(&reqs[i]));
            match shape {
                // Invalid requests never touch the engine, so they split
                // neither run.
                Some(Shape::Invalid(msg)) => {
                    out[i] = Some(Response::Error(msg));
                    self.batch.solo_requests.inc();
                }
                Some(Shape::Prefill) => {
                    if !decode.is_empty() {
                        self.execute_decode_run(reqs, &decode, &mut out);
                        decode.clear();
                    }
                    prefill.push(i);
                }
                Some(Shape::Sequential)
                    if matches!(&reqs[i], Request::Generate { .. }) =>
                {
                    if !prefill.is_empty() {
                        self.execute_prefill_run(reqs, &prefill, &mut out);
                        prefill.clear();
                    }
                    decode.push(i);
                }
                // Non-Generate sequential requests (Metrics) flush both
                // runs and answer solo at their admission position; the
                // end-of-window sentinel flushes whatever remains.
                Some(Shape::Sequential) | None => {
                    if !decode.is_empty() {
                        self.execute_decode_run(reqs, &decode, &mut out);
                        decode.clear();
                    }
                    if !prefill.is_empty() {
                        self.execute_prefill_run(reqs, &prefill, &mut out);
                        prefill.clear();
                    }
                    if i < reqs.len() {
                        out[i] = Some(self.handle(&reqs[i]));
                        self.batch.solo_requests.inc();
                    }
                }
            }
        }
        out.into_iter().map(|r| r.expect("every request answered")).collect()
    }

    /// One concatenated transformer forward over a run of validated
    /// prefill requests, then per-request response demux.
    fn execute_prefill_run(
        &self,
        reqs: &[Request],
        idxs: &[usize],
        out: &mut [Option<Response>],
    ) {
        if idxs.len() > 1 {
            self.batch.batched_requests.add(idxs.len() as u64);
        } else {
            self.batch.solo_requests.inc();
        }
        let seqs: Vec<&[u32]> = idxs
            .iter()
            .map(|&i| match &reqs[i] {
                Request::Score { tokens } => tokens.as_slice(),
                Request::Classify { tokens, .. } => tokens.as_slice(),
                Request::Generate { .. } => {
                    unreachable!("sequential requests never join a prefill run")
                }
            })
            .collect();
        let hook = self.hook();
        let _ = take_forward_faults();
        let (h, offsets) = {
            let _s = trace::span("forward");
            self.model.hidden_states_batch_hooked(&seqs, &hook)
        };
        let faults = take_forward_faults();
        let _head_span = trace::span("head");
        // One lm_head projection over every Score request's scored rows at
        // once (row-independent ⇒ bit-identical to per-request
        // projections). The final position of each request predicts
        // nothing, so its row is skipped — the serial path computes it
        // only as a side effect of the full-matrix matmul.
        let mut score_rows: Vec<usize> = Vec::new();
        for (k, &i) in idxs.iter().enumerate() {
            if matches!(&reqs[i], Request::Score { .. }) {
                score_rows.extend(offsets[k]..offsets[k + 1] - 1);
            }
        }
        let score_logits = (!score_rows.is_empty())
            .then(|| gather_rows(&h, &score_rows).matmul_nt(&self.model.lm_head));
        let mut cursor = 0usize;
        for (k, &i) in idxs.iter().enumerate() {
            match &reqs[i] {
                Request::Score { tokens } => {
                    let logits = score_logits.as_ref().expect("gathered above");
                    let mut total = 0.0f64;
                    for t in 0..tokens.len() - 1 {
                        let row = logits.row(cursor + t);
                        total += (row[tokens[t + 1] as usize] - logsumexp(row)) as f64;
                    }
                    cursor += tokens.len() - 1;
                    out[i] = Some(Response::Score(total / (tokens.len() - 1) as f64));
                }
                Request::Classify { task, .. } => {
                    let head = self.model.head(task).expect("validated");
                    let logits = head.matvec(h.row(offsets[k + 1] - 1));
                    let pred = logits
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .map(|(i, _)| i)
                        .unwrap();
                    out[i] = Some(Response::Classify(pred));
                }
                Request::Generate { .. } => unreachable!(),
            }
        }
        // Apply per-part fault attribution from the hook: an errored part's
        // demuxed answer (computed over zero-filled expert rows) is
        // replaced outright; a degraded part's answer is wrapped so the
        // approximation is visible. `part` indexes the window's
        // `part_offsets`, i.e. positions in `idxs`.
        for (part, msg) in faults.errors {
            out[idxs[part]] = Some(Response::Error(msg));
        }
        for part in faults.degraded {
            let i = idxs[part];
            if let Some(resp) = out[i].take() {
                out[i] = Some(match resp {
                    Response::Error(_) => resp,
                    r => Response::Degraded(Box::new(r)),
                });
            }
        }
    }

    /// Iteration-level continuous batching over a run of validated
    /// Generate requests: one layer-major forward per decode step over
    /// every active sequence ([`Model::decode_step_batch_hooked`]), with
    /// sequences admitted into the running batch as earlier ones retire —
    /// the decode analog of [`Engine::execute_prefill_run`].
    ///
    /// Parity is the RELAXED contract (module docs): each sequence's
    /// per-row kernels are bit-identical to its solo decode, but the
    /// interleaved serve order means the stateful cost model can answer a
    /// slot from a different arm (fused vs dense) than the serial
    /// reference would, so outputs agree bitwise only when the decisions
    /// do (e.g. roomy budgets). `tests/prop_decode.rs` pins the contract.
    ///
    /// KV admission is reservation-only: a sequence enters the batch only
    /// after leasing its worst-case page footprint from the shared
    /// [`KvPagePool`]; a refused lease falls back to the sequential path
    /// for that request (guaranteed progress) and NOTHING is ever revoked
    /// from a live sequence.
    fn execute_decode_run(
        &self,
        reqs: &[Request],
        idxs: &[usize],
        out: &mut [Option<Response>],
    ) {
        if idxs.len() == 1 || self.decode_max <= 1 {
            // Nothing to batch (or batching disabled): the sequential
            // reference path, bit-identical to pre-batching serving.
            for &i in idxs {
                out[i] = Some(self.handle(&reqs[i]));
                self.batch.solo_requests.inc();
            }
            return;
        }
        let _s = trace::span("decode.batch");
        let mut driver = DecodeDriver::new(self);
        let mut pending: VecDeque<usize> = idxs.iter().copied().collect();
        loop {
            // Admit while the batch has room — on the first pass this
            // fills the batch, afterwards it backfills slots freed by
            // retired sequences (the continuous-batching joins).
            while driver.has_room() {
                let Some(i) = pending.pop_front() else { break };
                match driver.admit(i, &reqs[i]) {
                    Some(resp) => {
                        self.batch.solo_requests.inc();
                        out[i] = Some(resp);
                    }
                    None => self.batch.batched_requests.inc(),
                }
            }
            let finished = driver.step();
            if finished.is_empty() && driver.is_idle() && pending.is_empty() {
                break;
            }
            for (i, resp) in finished {
                out[i] = Some(resp);
            }
        }
    }
}

/// One active sequence of a [`DecodeDriver`]: its KV cache stack, the KV
/// pool lease reserving its worst-case page footprint, and the fault
/// attribution accumulated across its steps.
struct LiveSeq {
    key: usize,
    caches: Vec<KvCache>,
    _lease: Option<KvLease>,
    error: Option<String>,
    degraded: bool,
}

/// The iteration-level decode loop, factored so two callers share one
/// implementation: [`Engine::execute_decode_run`] (batching the Generate
/// run of a single window) and the live server's per-worker decode lane
/// (admitting Generates from LATER windows into the running batch between
/// steps — cross-window continuous batching). Sequences are keyed by a
/// caller-chosen `usize` (request index / job slot) that comes back with
/// the finished response.
pub(crate) struct DecodeDriver<'e> {
    engine: &'e Engine,
    sched: DecodeScheduler,
    live: HashMap<u64, LiveSeq>,
}

impl<'e> DecodeDriver<'e> {
    pub(crate) fn new(engine: &'e Engine) -> DecodeDriver<'e> {
        DecodeDriver {
            engine,
            sched: DecodeScheduler::new(DecodePolicy { max_batch: engine.decode_max }),
            live: HashMap::new(),
        }
    }

    pub(crate) fn has_room(&self) -> bool {
        self.sched.has_room()
    }

    pub(crate) fn is_idle(&self) -> bool {
        self.sched.is_idle()
    }

    /// Admit a VALIDATED Generate request into the batch under `key`.
    /// Returns `None` when the sequence joined; `Some(response)` when the
    /// KV pool refused the lease and the request was served through the
    /// sequential path instead (the caller answers it immediately —
    /// guaranteed progress, and nothing is ever revoked from a live
    /// sequence to make room).
    pub(crate) fn admit(&mut self, key: usize, req: &Request) -> Option<Response> {
        debug_assert!(self.has_room(), "admit past decode batch cap");
        let Request::Generate { prompt, max_new } = req else {
            unreachable!("decode lanes hold only Generate requests")
        };
        let cfg = &self.engine.model.cfg;
        let want = (prompt.len() + max_new).min(cfg.max_seq);
        let lease = match self
            .engine
            .kv_pool
            .lease(kv_lease_bytes(want, cfg.d_model, cfg.n_layers))
        {
            Some(l) => {
                self.engine.decode.kv_leases.inc();
                Some(l)
            }
            None => {
                self.engine.decode.kv_refusals.inc();
                self.engine.decode.solo_fallbacks.inc();
                return Some(self.engine.handle(req));
            }
        };
        if !self.sched.is_idle() {
            self.engine.decode.joins.inc();
        }
        self.engine.decode.seqs.inc();
        let ticket = self.sched.admit(prompt.clone(), *max_new, cfg.max_seq);
        self.live.insert(
            ticket,
            LiveSeq {
                key,
                caches: self.engine.model.fresh_caches(),
                _lease: lease,
                error: None,
                degraded: false,
            },
        );
        None
    }

    /// One batched decode step over every active sequence (a no-op when
    /// idle). Returns the sequences that retired this step as
    /// `(key, response)` pairs; their KV leases are released on return.
    pub(crate) fn step(&mut self) -> Vec<(usize, Response)> {
        let plan = self.sched.plan();
        if plan.is_empty() {
            return Vec::new();
        }
        let engine = self.engine;
        let hook = engine.hook();
        let tokens: Vec<u32> = plan.iter().map(|&(_, t)| t).collect();
        let mut stacks: Vec<Vec<KvCache>> = plan
            .iter()
            .map(|&(tk, _)| std::mem::take(&mut self.live.get_mut(&tk).expect("live").caches))
            .collect();
        let _ = take_forward_faults();
        let logits = engine.model.decode_step_batch_hooked(&tokens, &mut stacks, &hook);
        // Fault attribution per STEP: the hook's part index is the row's
        // position in this step's plan, which maps back to one owning
        // sequence. Drained every step because retirements shift rows
        // between steps. First error wins per sequence, matching serial
        // attribution.
        let faults = take_forward_faults();
        for (part, msg) in faults.errors {
            let s = self.live.get_mut(&plan[part].0).expect("live");
            if s.error.is_none() {
                s.error = Some(msg);
            }
        }
        for part in faults.degraded {
            self.live.get_mut(&plan[part].0).expect("live").degraded = true;
        }
        for (k, &(tk, _)) in plan.iter().enumerate() {
            self.live.get_mut(&tk).expect("live").caches = std::mem::take(&mut stacks[k]);
        }
        engine.decode.record_step(plan.len());
        let mut out = Vec::new();
        for fin in self.sched.record(&logits) {
            let s = self.live.remove(&fin.ticket).expect("finished seq is live");
            out.push((
                s.key,
                match (s.error, s.degraded) {
                    (Some(msg), _) => Response::Error(msg),
                    (None, true) => {
                        Response::Degraded(Box::new(Response::Generate(fin.produced)))
                    }
                    (None, false) => Response::Generate(fin.produced),
                },
            ));
            // `s._lease` drops here, returning the KV pages.
        }
        out
    }
}

/// Per-request fault attribution carried from the FFN hook (whose
/// [`FfnHook`] signature has no error channel) back to the request/response
/// layer. The hook runs on the calling thread, so a thread-local is exact:
/// `handle_inner` / `execute_prefill_run` drain it before the forward (any
/// stale state from a panicked predecessor is discarded) and apply it
/// after — part-indexed errors turn into [`Response::Error`] for exactly
/// the requests whose rows routed to the failing expert, and degraded
/// parts wrap their answers in [`Response::Degraded`]. `part` is the
/// request's index inside the window's `part_offsets` (always 0 on the
/// serial path).
#[derive(Default)]
struct ForwardFaults {
    /// Parts that received at least one barycenter-degraded serve.
    degraded: Vec<usize>,
    /// First serve error per part, in the order parts first failed.
    errors: Vec<(usize, String)>,
}

thread_local! {
    static FORWARD_FAULTS: std::cell::RefCell<ForwardFaults> =
        const { std::cell::RefCell::new(ForwardFaults { degraded: Vec::new(), errors: Vec::new() }) };
}

fn take_forward_faults() -> ForwardFaults {
    FORWARD_FAULTS.with(|f| std::mem::take(&mut *f.borrow_mut()))
}

fn note_degraded_part(part: usize) {
    FORWARD_FAULTS.with(|f| {
        let mut f = f.borrow_mut();
        if !f.degraded.contains(&part) {
            f.degraded.push(part);
        }
    });
}

/// First error wins per part — the same attribution serial serving
/// produces, where a request fails on the first slot whose serve errors.
fn note_part_error(part: usize, msg: String) {
    FORWARD_FAULTS.with(|f| {
        let mut f = f.borrow_mut();
        if !f.errors.iter().any(|(p, _)| *p == part) {
            f.errors.push((part, msg));
        }
    });
}

/// The FFN hook routing compressed blocks through the restore cache's
/// cost-model serve path: hot experts run dense from the cache, cold ones
/// run restore-free through the fused layer (monolithic mode) or the paged
/// center + single-expert pieces (store mode), with the center term
/// computed at most once per batch window. In store mode the slots a block
/// routed to become the prefetch prediction for the next compressed block.
struct EngineHook<'a> {
    model: &'a Model,
    cache: Option<&'a ExpertCache>,
    prefetcher: Option<&'a Prefetcher>,
    next_block: &'a HashMap<usize, usize>,
    batch: &'a BatchCounters,
}

impl FfnHook for EngineHook<'_> {
    fn ffn_forward(&self, block: usize, x: &Matrix) -> Option<Matrix> {
        let cache = self.cache?;
        let Ffn::Moe(layer) = &self.model.blocks[block].ffn else {
            return None;
        };
        if !cache.has_layer(block) {
            return None;
        }
        // Route with the resident router; serve each activated slot through
        // the cache's fused-vs-restore decision. The cache synchronizes
        // itself with short metadata critical sections and per-key
        // singleflight — fetches, decodes, restores, and every expert
        // forward here run without any global lock, so concurrent requests
        // overlap even while cold-missing (the Arc'd weights outlive the
        // cache's internal guards). The shared center term is built lazily
        // on the first fused slot and reused by the rest of the batch.
        let mut block_span = trace::span("moe.block");
        block_span.block(block);
        let mut shared: Option<SharedAct> = None;
        let mut routed: Vec<usize> = Vec::new();
        let out = route_dispatch_combine(
            &layer.router,
            x,
            None,
            layer.shared_expert.as_ref(),
            |slot, sub, rows| {
                routed.push(slot);
                // try_serve so a store fetch/integrity error returns as a
                // value instead of panicking mid-dispatch; the error is
                // pinned on this request through the thread-local fault
                // record and turns into Response::Error after the forward —
                // the zero-filled rows below are never served.
                let decision = {
                    let mut s = trace::span("moe.serve");
                    s.key(block, slot);
                    cache.try_serve(block, slot, sub.rows)
                };
                let mut d = trace::span("moe.dispatch");
                d.key(block, slot);
                match decision {
                    Ok(Serve::Dense(expert)) => expert.forward(sub),
                    Ok(Serve::Fused(fl)) => {
                        let sh = shared.get_or_insert_with(|| fl.shared_act(x));
                        fl.forward_slot(slot, sub, &sh.gather(rows))
                    }
                    Ok(Serve::Paged { center, expert }) => {
                        let sh = shared.get_or_insert_with(|| center_shared_act(&center, x));
                        fused_forward_expert(&center, &expert, sub, &sh.gather(rows))
                    }
                    Ok(Serve::Degraded(center)) => {
                        // Barycenter-only answer for this slot (the paper's
                        // rate→0 limit); the response is wrapped in
                        // Response::Degraded so the approximation is never
                        // silent.
                        note_degraded_part(0);
                        center.forward(sub)
                    }
                    Err(e) => {
                        note_part_error(0, format!("expert serve failed for block {block}: {e:#}"));
                        Matrix::zeros(sub.rows, x.cols)
                    }
                }
            },
        );
        // Router-predicted prefetch: expert choice is strongly correlated
        // across adjacent MoE blocks (upcycled experts in particular), so
        // the slots this block activated are the best zero-cost prediction
        // for the next compressed block. Fire-and-forget on the pool; the
        // cache lock is NOT held here.
        if let (Some(pf), Some(&nb)) = (self.prefetcher, self.next_block.get(&block)) {
            let keys: Vec<(usize, usize)> = routed.iter().map(|&s| (nb, s)).collect();
            pf.request(&keys);
        }
        Some(out)
    }

    /// The continuous-batching layer forward: `x` row-concatenates the
    /// window's requests (`part_offsets` boundaries). Routing runs once;
    /// cache decisions replay in serial (request-major) order through
    /// [`ExpertCache::try_serve_batch`]; then each slot's rows dispatch in
    /// fused segments — adjacent requests whose serves share the same
    /// weight objects run through ONE forward, with the center `SharedAct`
    /// built at most once over the combined rows for the whole window.
    fn ffn_forward_batch(
        &self,
        block: usize,
        x: &Matrix,
        part_offsets: &[usize],
    ) -> Option<Matrix> {
        let cache = self.cache?;
        let Ffn::Moe(layer) = &self.model.blocks[block].ffn else {
            return None;
        };
        if !cache.has_layer(block) {
            return None;
        }
        let mut block_span = trace::span("moe.block");
        block_span.block(block);
        let groups = {
            let mut s = trace::span("moe.route");
            s.block(block);
            route_groups(&layer.router, x, None)
        };
        let slot_parts: Vec<Vec<(usize, usize)>> =
            groups.iter().map(|g| group_parts(g, part_offsets)).collect();
        // Serial-order want list: requests in admission order, each
        // request's activated slots ascending — exactly the serve sequence
        // the serial engine would issue, so decisions and metrics replay
        // bit-identically.
        let n_parts = part_offsets.len() - 1;
        let mut wants: Vec<(usize, usize)> = Vec::new();
        let mut want_of: HashMap<(usize, usize), usize> = HashMap::new();
        for part in 0..n_parts {
            for (slot, parts) in slot_parts.iter().enumerate() {
                if let Some(&(_, len)) = parts.iter().find(|&&(p, _)| p == part) {
                    want_of.insert((slot, part), wants.len());
                    wants.push((slot, len));
                }
            }
        }
        // Per-want results: a store error on one request's serve is pinned
        // on THAT request (matching serial attribution exactly — same
        // first-failing-slot, same message) while the rest of the window
        // still gets bit-exact answers.
        let serves: Vec<Result<Serve>> = {
            let mut s = trace::span("moe.serve");
            s.block(block);
            cache.try_serve_batch(block, &wants)
        };
        let mut out = match layer.shared_expert.as_ref() {
            Some(se) => se.forward(x),
            None => Matrix::zeros(x.rows, x.cols),
        };
        let mut shared: Option<SharedAct> = None;
        let mut routed: Vec<usize> = Vec::new();
        let mut dispatch_rows: Vec<usize> = Vec::new();
        for (slot, group) in groups.iter().enumerate() {
            if group.is_empty() {
                continue;
            }
            routed.push(slot);
            let rows: Vec<usize> = group.iter().map(|&(t, _)| t).collect();
            // Fuse adjacent per-request segments whose serves share the
            // same weight objects; each fused segment runs ONE forward
            // (row-independent kernels ⇒ bit-identical to per-request
            // calls). Rows are gathered per segment straight from `x` —
            // one copy into the dispatch layout.
            let mut segments: Vec<(usize, usize, Serve)> = Vec::new();
            let mut pos = 0usize;
            for &(part, len) in &slot_parts[slot] {
                match &serves[want_of[&(slot, part)]] {
                    Ok(serve) => {
                        if matches!(serve, Serve::Degraded(_)) {
                            note_degraded_part(part);
                        }
                        // A failed part leaves a gap in the row range, so
                        // fusing additionally requires contiguity.
                        let extend = matches!(segments.last(),
                            Some((_, hi, s)) if *hi == pos && s.same_source(serve));
                        if extend {
                            segments.last_mut().expect("checked nonempty").1 = pos + len;
                        } else {
                            segments.push((pos, pos + len, serve.clone()));
                        }
                    }
                    Err(e) => {
                        // The part's rows stay zero in `out`; its response
                        // is replaced with Response::Error after the
                        // forward, so the zeros are never served.
                        note_part_error(
                            part,
                            format!("expert serve failed for block {block}: {e:#}"),
                        );
                    }
                }
                pos += len;
            }
            debug_assert_eq!(pos, rows.len());
            for (lo, hi, serve) in segments {
                let mut d = trace::span("moe.dispatch");
                d.key(block, slot);
                let sub_seg = gather_rows(x, &rows[lo..hi]);
                let y = match serve {
                    Serve::Dense(expert) => expert.forward(&sub_seg),
                    Serve::Fused(fl) => {
                        let sh = shared.get_or_insert_with(|| fl.shared_act(x));
                        fl.forward_slot(slot, &sub_seg, &sh.gather(&rows[lo..hi]))
                    }
                    Serve::Paged { center, expert } => {
                        let sh = shared.get_or_insert_with(|| center_shared_act(&center, x));
                        fused_forward_expert(&center, &expert, &sub_seg, &sh.gather(&rows[lo..hi]))
                    }
                    Serve::Degraded(center) => center.forward(&sub_seg),
                };
                combine_slot_output(&mut out, &group[lo..hi], &y);
                dispatch_rows.push(hi - lo);
            }
        }
        for &r in &dispatch_rows {
            self.batch.record_dispatch(r);
        }
        if let (Some(pf), Some(&nb)) = (self.prefetcher, self.next_block.get(&block)) {
            let keys: Vec<(usize, usize)> = routed.iter().map(|&s| (nb, s)).collect();
            pf.request(&keys);
        }
        Some(out)
    }
}

// ------------------------------------------------------------------ server

struct Job {
    req: Request,
    submitted: Instant,
    reply: Sender<(Response, Duration)>,
}

/// Render a worker-loop panic payload as the error message every affected
/// request answers with.
fn panic_msg(payload: Box<dyn std::any::Any + Send>) -> String {
    let msg = payload
        .downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| payload.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "unknown panic".into());
    format!("engine panicked while serving: {msg}")
}

/// Thread-pool server with cross-request continuous batching: each worker
/// drains whole admission windows and executes them through
/// [`Engine::handle_batch`].
pub struct Server {
    tx: Option<Sender<Job>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    /// Lock-free `server.*` instruments on the engine's registry — workers
    /// record request latencies and window sizes without a mutex.
    stats: ServerStats,
    registry: Arc<Registry>,
    started: Instant,
    /// Requests submitted but not yet executed or shed — the admission
    /// control signal. Incremented in [`Server::submit`], decremented by
    /// workers as they drain windows.
    depth: Arc<AtomicUsize>,
    max_queue: usize,
}

impl Server {
    pub fn start(engine: Engine, cfg: ServerConfig) -> Server {
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let stats = ServerStats::new(engine.registry());
        let registry = engine.registry().clone();
        let depth = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        let policy =
            BatchPolicy { max_batch: cfg.batch_max.max(1), linger_us: cfg.batch_wait_us };
        let deadline_ms = cfg.deadline_ms;
        for _ in 0..cfg.workers.max(1) {
            let rx = rx.clone();
            let engine = engine.clone();
            let stats = stats.clone();
            let depth = depth.clone();
            handles.push(std::thread::spawn(move || {
                let mut batcher = Batcher::new(policy);
                let epoch = Instant::now();
                // The worker's decode lane: Generate jobs peel off their
                // windows into a batch that PERSISTS across windows, so a
                // Generate arriving three windows later joins sequences
                // already mid-decode (iteration-level continuous
                // batching). While the lane is active the worker polls
                // for new windows between steps instead of blocking.
                let mut driver = DecodeDriver::new(&engine);
                let mut lane: HashMap<usize, (Instant, Sender<(Response, Duration)>)> =
                    HashMap::new();
                let mut waiting: VecDeque<(usize, Request)> = VecDeque::new();
                let mut next_key = 0usize;
                loop {
                    let lane_idle = driver.is_idle() && waiting.is_empty();
                    // Hold the receiver lock only while forming one window;
                    // execution runs unlocked so workers overlap. An idle
                    // lane blocks exactly like the pre-decode-lane worker;
                    // an active lane must keep stepping, so it only polls.
                    let window = {
                        let guard = rx.lock().unwrap();
                        if lane_idle {
                            next_window(&guard, &mut batcher, epoch)
                        } else {
                            poll_window(&guard, &mut batcher, epoch)
                        }
                    };
                    if window.is_none() && lane_idle {
                        // Blocking pickup returns None only when the
                        // channel is closed and the batcher drained.
                        break;
                    }
                    if let Some(window) = window {
                        depth.fetch_sub(window.items.len(), Ordering::Relaxed);
                        engine.note_flush(window.reason, window.waited_us);
                        // Deadline shedding: a job still queued past its
                        // deadline answers Overloaded instead of executing
                        // doomed work that its client has given up on. With
                        // deadline_ms == 0 this branch never runs and the
                        // window executes exactly as admitted.
                        let mut items = window.items;
                        if deadline_ms > 0 {
                            let deadline = Duration::from_millis(deadline_ms);
                            let now = Instant::now();
                            let mut live = Vec::with_capacity(items.len());
                            for j in items {
                                if now.saturating_duration_since(j.submitted) > deadline {
                                    stats.record_shed();
                                    let _ = j.reply.send((
                                        Response::Overloaded(
                                            "deadline exceeded before execution".into(),
                                        ),
                                        j.submitted.elapsed(),
                                    ));
                                } else {
                                    live.push(j);
                                }
                            }
                            items = live;
                        }
                        let size = items.len();
                        let tokens: u64 = items.iter().map(|j| j.req.token_count()).sum();
                        // Peel valid Generates into the decode lane (when
                        // batching is enabled); everything else executes
                        // through the window path below. Invalid Generates
                        // stay in the window so validation answers them.
                        let mut rest: Vec<Job> = Vec::with_capacity(items.len());
                        for j in items {
                            let decodes = engine.decode_max > 1
                                && matches!(j.req, Request::Generate { .. })
                                && matches!(engine.shape(&j.req), Shape::Sequential);
                            if decodes {
                                let key = next_key;
                                next_key += 1;
                                lane.insert(key, (j.submitted, j.reply));
                                waiting.push_back((key, j.req));
                            } else {
                                rest.push(j);
                            }
                        }
                        if size > 0 {
                            stats.record_batch(size, tokens);
                        }
                        if !rest.is_empty() {
                            // Decompose jobs so handle_batch borrows the
                            // owned requests — no token-buffer clones on
                            // the hot path.
                            let n = rest.len();
                            let (reqs, replies): (Vec<Request>, Vec<(Instant, Sender<_>)>) =
                                rest.into_iter()
                                    .map(|j| (j.req, (j.submitted, j.reply)))
                                    .unzip();
                            // Per-request admission waits feed the traces'
                            // `queue.wait` spans; the clock reads are
                            // skipped entirely when tracing is off.
                            let queue_waits: Option<Vec<u64>> = trace::enabled().then(|| {
                                let now = Instant::now();
                                replies
                                    .iter()
                                    .map(|(sub, _)| {
                                        now.saturating_duration_since(*sub).as_nanos() as u64
                                    })
                                    .collect()
                            });
                            // Store and integrity failures are handled
                            // inside the engine (per-request error pinning,
                            // degraded serves), so this catch_unwind is a
                            // last-resort backstop for genuine bugs: a
                            // panic must not take the worker down — answer
                            // every request of THIS window with an error
                            // carrying the panic message and keep draining.
                            let responses = catch_unwind(AssertUnwindSafe(|| {
                                engine.handle_batch_traced(&reqs, queue_waits.as_deref())
                            }))
                            .unwrap_or_else(|payload| {
                                vec![Response::Error(panic_msg(payload)); n]
                            });
                            debug_assert_eq!(responses.len(), n);
                            for ((submitted, reply), resp) in
                                replies.into_iter().zip(responses)
                            {
                                let latency = submitted.elapsed();
                                let _ = reply.send((resp, latency));
                                stats.record_request(latency);
                            }
                        }
                    }
                    // Backfill the decode batch from the waiting queue
                    // (sheds stale jobs first), then run ONE step; newly
                    // freed slots and newly polled windows are picked up
                    // on the next loop iteration.
                    while driver.has_room() {
                        let Some((key, req)) = waiting.pop_front() else { break };
                        let submitted = lane[&key].0;
                        if deadline_ms > 0
                            && submitted.elapsed() > Duration::from_millis(deadline_ms)
                        {
                            let (submitted, reply) = lane.remove(&key).expect("waiting");
                            stats.record_shed();
                            let _ = reply.send((
                                Response::Overloaded(
                                    "deadline exceeded before decode admission".into(),
                                ),
                                submitted.elapsed(),
                            ));
                            continue;
                        }
                        match driver.admit(key, &req) {
                            Some(resp) => {
                                let (submitted, reply) =
                                    lane.remove(&key).expect("waiting");
                                engine.batch.solo_requests.inc();
                                let latency = submitted.elapsed();
                                let _ = reply.send((resp, latency));
                                stats.record_request(latency);
                            }
                            None => engine.batch.batched_requests.inc(),
                        }
                    }
                    if !driver.is_idle() {
                        let finished =
                            catch_unwind(AssertUnwindSafe(|| driver.step()));
                        match finished {
                            Ok(finished) => {
                                for (key, resp) in finished {
                                    let (submitted, reply) =
                                        lane.remove(&key).expect("lane job");
                                    let latency = submitted.elapsed();
                                    let _ = reply.send((resp, latency));
                                    stats.record_request(latency);
                                }
                            }
                            Err(payload) => {
                                // A panicked step poisons the whole lane:
                                // answer every in-flight and waiting job
                                // with the panic error and start a fresh
                                // driver (leases drop with the old one).
                                let msg = panic_msg(payload);
                                for (_, (submitted, reply)) in lane.drain() {
                                    let latency = submitted.elapsed();
                                    let _ =
                                        reply.send((Response::Error(msg.clone()), latency));
                                    stats.record_request(latency);
                                }
                                waiting.clear();
                                driver = DecodeDriver::new(&engine);
                            }
                        }
                    }
                }
            }));
        }
        Server {
            tx: Some(tx),
            handles,
            stats,
            registry,
            started: Instant::now(),
            depth,
            max_queue: cfg.max_queue,
        }
    }

    /// Submit a request; the receiver yields (response, latency).
    ///
    /// With `max_queue > 0`, admission control sheds here: a submit that
    /// would push the in-flight depth past the limit answers
    /// [`Response::Overloaded`] immediately (on the returned receiver)
    /// without enqueueing — bounded queueing delay instead of unbounded
    /// tail latency under overload.
    pub fn submit(&self, req: Request) -> Receiver<(Response, Duration)> {
        let (reply_tx, reply_rx) = channel();
        let d = self.depth.fetch_add(1, Ordering::Relaxed);
        if self.max_queue > 0 && d >= self.max_queue {
            self.depth.fetch_sub(1, Ordering::Relaxed);
            self.stats.record_shed();
            let _ = reply_tx.send((
                Response::Overloaded(format!("queue full ({} in flight)", self.max_queue)),
                Duration::ZERO,
            ));
            return reply_rx;
        }
        let job = Job { req, submitted: Instant::now(), reply: reply_tx };
        self.tx.as_ref().expect("server running").send(job).expect("workers alive");
        reply_rx
    }

    /// Live snapshot of every instrument (server + batch + cache) without
    /// stopping the server — safe to call from any thread at any time.
    pub fn metrics_snapshot(&self) -> MetricsSnapshot {
        self.registry.snapshot()
    }

    /// Drain and stop, returning the aggregated metrics.
    pub fn shutdown(mut self) -> ServerMetrics {
        drop(self.tx.take());
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        self.stats.snapshot(self.started.elapsed().as_secs_f64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{compress_model, ResMoE};
    use crate::moe::ModelConfig;
    use crate::util::Rng;

    fn tiny_model(seed: u64) -> Model {
        let mut cfg = ModelConfig::switch_mini(4);
        cfg.d_model = 16;
        cfg.d_inner = 32;
        cfg.n_layers = 2;
        cfg.n_heads = 2;
        cfg.vocab_size = 32;
        cfg.max_seq = 32;
        let mut rng = Rng::new(seed);
        Model::random(&cfg, &mut rng)
    }

    #[test]
    fn server_config_from_lookup_checked_parsing() {
        let env = |pairs: &'static [(&'static str, &'static str)]| {
            move |name: &str| {
                pairs.iter().find(|(k, _)| *k == name).map(|(_, v)| v.to_string())
            }
        };
        // Happy path.
        let c = ServerConfig::from_lookup(env(&[
            ("RESMOE_MAX_QUEUE", "12"),
            ("RESMOE_DEADLINE_MS", "250"),
            ("RESMOE_BATCH", "4"),
        ]));
        assert_eq!((c.max_queue, c.deadline_ms, c.batch_max), (12, 250, 4));
        // Unset → documented defaults (0 = unbounded / no deadline).
        let c = ServerConfig::from_lookup(|_| None);
        assert_eq!((c.max_queue, c.deadline_ms), (0, 0));
        // Garbage → default, consistently across all knobs.
        let c = ServerConfig::from_lookup(env(&[
            ("RESMOE_MAX_QUEUE", "lots"),
            ("RESMOE_DEADLINE_MS", "-5"),
        ]));
        assert_eq!((c.max_queue, c.deadline_ms), (0, 0));
        // Overflow-wide digits saturate. Pre-fix, parse() failed and
        // RESMOE_MAX_QUEUE="99…9" silently meant UNBOUNDED (0) — the
        // opposite of the operator's intent.
        let c = ServerConfig::from_lookup(env(&[
            ("RESMOE_MAX_QUEUE", "99999999999999999999999999"),
            ("RESMOE_DEADLINE_MS", "99999999999999999999999999"),
        ]));
        assert_eq!(c.max_queue, usize::MAX);
        assert_eq!(c.deadline_ms, u64::MAX);
    }

    #[test]
    fn cached_engine_matches_restored_model() {
        // The serving hot path (lazy restore through the cache) must produce
        // EXACTLY the offline restored model's outputs.
        let m = tiny_model(1);
        let mut rng = Rng::new(2);
        let cm = compress_model(&m, &ResMoE::up(), 0.25, 1, None, &mut rng);
        let engine = Engine::compressed(m.clone(), cm.layers.clone(), usize::MAX);
        let tokens: Vec<u32> = vec![1, 5, 9, 2, 8, 3];
        let hook_out = match engine.handle(&Request::Score { tokens: tokens.clone() }) {
            Response::Score(s) => s,
            other => panic!("{other:?}"),
        };
        // Offline: fully restored model.
        let offline = Engine::dense(cm.model.clone());
        let want = match offline.handle(&Request::Score { tokens }) {
            Response::Score(s) => s,
            other => panic!("{other:?}"),
        };
        assert!((hook_out - want).abs() < 1e-5, "{hook_out} vs {want}");
    }

    #[test]
    fn generate_matches_restored_model() {
        let m = tiny_model(3);
        let mut rng = Rng::new(4);
        let cm = compress_model(&m, &ResMoE::up(), 0.25, 1, None, &mut rng);
        let engine = Engine::compressed(m.clone(), cm.layers.clone(), usize::MAX);
        let got = engine.handle(&Request::Generate { prompt: vec![1, 2, 3], max_new: 6 });
        let want = Response::Generate(cm.model.generate(&[1, 2, 3], 6));
        assert_eq!(got, want);
    }

    #[test]
    fn thrashed_engine_serves_fused_and_matches_restored_model() {
        // Budget below one restored expert: every MoE block runs restore-
        // free, and the score must still equal the offline restored model.
        let m = tiny_model(10);
        let mut rng = Rng::new(11);
        let cm = compress_model(&m, &ResMoE::up(), 0.25, 2, None, &mut rng);
        let expert_bytes = 0; // force thrash with a zero budget
        let engine = Engine::compressed(m.clone(), cm.layers.clone(), expert_bytes);
        let tokens: Vec<u32> = vec![2, 7, 1, 9, 4, 3, 8];
        let got = match engine.handle(&Request::Score { tokens: tokens.clone() }) {
            Response::Score(s) => s,
            other => panic!("{other:?}"),
        };
        let offline = Engine::dense(cm.model.clone());
        let want = match offline.handle(&Request::Score { tokens }) {
            Response::Score(s) => s,
            other => panic!("{other:?}"),
        };
        assert!((got - want).abs() < 1e-5, "{got} vs {want}");
        let metrics = engine.cache_metrics().unwrap();
        assert!(metrics.fused_serves > 0, "thrash budget must use the fused path");
        assert_eq!(metrics.restore_serves, 0);
        // Restore-only policy agrees numerically (A/B switch).
        let engine_restore = Engine::compressed(m, cm.layers, expert_bytes);
        engine_restore.set_fused(false);
        let got_restore =
            match engine_restore.handle(&Request::Score { tokens: vec![2, 7, 1, 9, 4, 3, 8] }) {
                Response::Score(s) => s,
                other => panic!("{other:?}"),
            };
        assert!((got_restore - want).abs() < 1e-5);
        let m2 = engine_restore.cache_metrics().unwrap();
        assert_eq!(m2.fused_serves, 0);
        assert!(m2.restore_serves > 0);
    }

    #[test]
    fn error_responses() {
        let engine = Engine::dense(tiny_model(5));
        assert!(matches!(
            engine.handle(&Request::Score { tokens: vec![1] }),
            Response::Error(_)
        ));
        assert!(matches!(
            engine.handle(&Request::Classify { task: "none".into(), tokens: vec![1, 2] }),
            Response::Error(_)
        ));
        // Over-long classify inputs now error instead of panicking (the
        // batched path needs the validation, and serial must agree).
        let long: Vec<u32> = (0..40).map(|t| t % 32).collect();
        assert!(matches!(
            engine.handle(&Request::Classify { task: "none".into(), tokens: long }),
            Response::Error(_)
        ));
    }

    #[test]
    fn server_roundtrip_under_load() {
        let m = tiny_model(6);
        let mut rng = Rng::new(7);
        let cm = compress_model(&m, &ResMoE::up(), 0.25, 1, None, &mut rng);
        let engine = Engine::compressed(m, cm.layers, 1 << 20);
        let server = Server::start(
            engine,
            ServerConfig { batch_max: 4, batch_wait_us: 200, workers: 2, ..Default::default() },
        );
        let replies: Vec<_> = (0..16)
            .map(|i| {
                server.submit(Request::Score {
                    tokens: (0..8).map(|t| ((t + i) % 32) as u32).collect(),
                })
            })
            .collect();
        for r in replies {
            let (resp, latency) = r.recv().unwrap();
            assert!(matches!(resp, Response::Score(_)), "{resp:?}");
            assert!(latency.as_secs() < 5);
        }
        let metrics = server.shutdown();
        assert_eq!(metrics.requests, 16);
        assert_eq!(metrics.latency_us.count, 16);
        assert!(metrics.mean_batch() >= 1.0);
    }

    #[test]
    fn metrics_request_answers_inline_with_prometheus_text() {
        let m = tiny_model(40);
        let mut rng = Rng::new(41);
        let cm = compress_model(&m, &ResMoE::up(), 0.25, 1, None, &mut rng);
        let engine = Engine::compressed(m, cm.layers, usize::MAX);
        // Warm the cache so the exposition has counters to show.
        assert!(matches!(
            engine.handle(&Request::Score { tokens: vec![1, 5, 9, 2] }),
            Response::Score(_)
        ));
        let server = Server::start(
            engine,
            ServerConfig { batch_max: 4, batch_wait_us: 200, workers: 1, ..Default::default() },
        );
        let (resp, _) = server.submit(Request::Metrics).recv().unwrap();
        let Response::Metrics(text) = resp else { panic!("{resp:?}") };
        assert!(text.contains("resmoe_cache_hits"), "{text}");
        assert!(text.contains("resmoe_batch_windows"), "{text}");
        assert!(text.contains("resmoe_server_latency_us_count"), "{text}");
        // The live snapshot is also reachable without a request.
        let snap = server.metrics_snapshot();
        assert!(snap.counter("cache.misses").unwrap_or(0) > 0);
        server.shutdown();
    }

    #[test]
    fn handle_batch_is_bit_identical_to_serial_handles() {
        // The tentpole contract in miniature (the full property test lives
        // in tests/prop_batching.rs): one window == the same requests
        // served one-at-a-time, EXACTLY, across roomy/thrash/tight budgets.
        let m = tiny_model(30);
        let mut rng = Rng::new(31);
        let cm = compress_model(&m, &ResMoE::up(), 0.25, 1, None, &mut rng);
        let one_expert = 32 * (2 * 16 + 1) * 4 + 16 * 4;
        let reqs: Vec<Request> = (0..6)
            .map(|i| Request::Score {
                tokens: (0..4 + i).map(|t| ((t * (i + 2) + 1) % 32) as u32).collect(),
            })
            .collect();
        for budget in [usize::MAX, 0, one_expert, 2 * one_expert] {
            let serial = Engine::compressed(m.clone(), cm.layers.clone(), budget);
            let want: Vec<Response> = reqs.iter().map(|r| serial.handle(r)).collect();
            let batched = Engine::compressed(m.clone(), cm.layers.clone(), budget);
            let got = batched.handle_batch(&reqs);
            assert_eq!(got, want, "budget {budget}: batched must equal serial bitwise");
            let (ms, mb) = (
                serial.cache_metrics().unwrap(),
                batched.cache_metrics().unwrap(),
            );
            assert_eq!(ms.hits, mb.hits, "budget {budget}");
            assert_eq!(ms.misses, mb.misses, "budget {budget}");
            assert_eq!(ms.evictions, mb.evictions, "budget {budget}");
            assert_eq!(ms.restore_serves, mb.restore_serves, "budget {budget}");
            assert_eq!(ms.fused_serves, mb.fused_serves, "budget {budget}");
            let bm = batched.batch_metrics();
            assert_eq!(bm.windows, 1);
            assert_eq!(bm.batched_requests, 6);
        }
    }

    #[test]
    fn handle_batch_mixed_window_matches_serial_order() {
        // Score runs split around a Generate (sequential) request; an
        // invalid request answers inline without splitting the run. The
        // whole window must equal the serial reference exactly.
        let mut m = tiny_model(32);
        let mut rng = Rng::new(33);
        m.heads.push(("nli".into(), Matrix::randn(3, m.cfg.d_model, 0.2, &mut rng)));
        let cm = compress_model(&m, &ResMoE::up(), 0.25, 1, None, &mut rng);
        let reqs = vec![
            Request::Score { tokens: vec![1, 5, 9, 2] },
            Request::Score { tokens: vec![3, 3, 7] },
            Request::Generate { prompt: vec![1, 2, 3], max_new: 4 },
            Request::Score { tokens: vec![1] }, // invalid: answered inline
            Request::Classify { task: "nli".into(), tokens: vec![4, 5, 6] },
            Request::Score { tokens: vec![8, 2, 2, 9, 1] },
        ];
        let serial = Engine::compressed(m.clone(), cm.layers.clone(), usize::MAX);
        let want: Vec<Response> = reqs.iter().map(|r| serial.handle(r)).collect();
        let batched = Engine::compressed(m.clone(), cm.layers.clone(), usize::MAX);
        let got = batched.handle_batch(&reqs);
        assert_eq!(got, want);
        assert!(matches!(got[3], Response::Error(_)));
        let bm = batched.batch_metrics();
        // Runs: [0, 1] batched; 2 solo (generate); 3 solo (invalid);
        // [4, 5] batched.
        assert_eq!(bm.batched_requests, 4);
        assert_eq!(bm.solo_requests, 2);
        assert!(bm.expert_dispatches > 0, "batched runs must record dispatches");
    }

    #[test]
    fn batched_server_records_window_metrics() {
        let m = tiny_model(34);
        let mut rng = Rng::new(35);
        let cm = compress_model(&m, &ResMoE::up(), 0.25, 1, None, &mut rng);
        let engine = Engine::compressed(m, cm.layers, usize::MAX);
        let server = Server::start(
            engine.clone(),
            ServerConfig { batch_max: 4, batch_wait_us: 3000, workers: 1, ..Default::default() },
        );
        let replies: Vec<_> = (0..10)
            .map(|i| {
                server.submit(Request::Score {
                    tokens: (0..6).map(|t| ((t + i) % 32) as u32).collect(),
                })
            })
            .collect();
        for r in replies {
            assert!(matches!(r.recv().unwrap().0, Response::Score(_)));
        }
        server.shutdown();
        let bm = engine.batch_metrics();
        assert!(bm.windows > 0);
        assert_eq!(bm.batched_requests + bm.solo_requests, 10);
        assert_eq!(
            bm.full_flushes + bm.linger_flushes + bm.closed_flushes,
            bm.windows,
            "every window came from a recorded flush: {bm:?}"
        );
        assert!(bm.occupancy.iter().sum::<u64>() == bm.windows);
    }

    #[test]
    fn store_engine_matches_monolithic_engine_bit_for_bit() {
        // Pack → serve must equal the monolithic-load engine EXACTLY: the
        // shard codec round-trips f32 bits, the cost model sees identical
        // dense occupancy in both modes, and the paged fused path runs the
        // same arithmetic as the monolithic fused path.
        use crate::store::pack_compressed_model;
        let m = tiny_model(20);
        let mut rng = Rng::new(21);
        let cm = compress_model(&m, &ResMoE::up(), 0.25, 2, None, &mut rng);
        let dir = std::env::temp_dir().join("resmoe-server-store-test");
        std::fs::create_dir_all(&dir).unwrap();
        let artifact = dir.join("engine.rmes");
        pack_compressed_model(&m, &cm.layers, 0.25, &artifact).unwrap();
        let reqs: Vec<Request> = (0..6)
            .map(|i| Request::Score {
                tokens: (0..10).map(|t| ((t * (i + 3) + 1) % 32) as u32).collect(),
            })
            .collect();
        // Same budgets → same decisions → identical outputs, across warm,
        // thrash, and tight budgets.
        let one_expert = 32 * (2 * 16 + 1) * 4 + 16 * 4; // pi*(2p+1)+p floats
        for budget in [usize::MAX, 0, one_expert, 2 * one_expert] {
            let mono = Engine::compressed(m.clone(), cm.layers.clone(), budget);
            let mut packed = Engine::from_store(&artifact, budget).unwrap();
            packed.disable_prefetch(); // deterministic decision sequence
            for req in &reqs {
                let a = mono.handle(req);
                let b = packed.handle(req);
                assert_eq!(a, b, "budget {budget}: packed engine must match exactly");
            }
        }
    }

    #[test]
    fn store_engine_batched_window_matches_serial_bit_for_bit() {
        // The same parity through the artifact path: one batched window
        // over a packed engine == serial serving of the same requests.
        use crate::store::pack_compressed_model;
        let m = tiny_model(36);
        let mut rng = Rng::new(37);
        let cm = compress_model(&m, &ResMoE::up(), 0.25, 2, None, &mut rng);
        let dir = std::env::temp_dir().join("resmoe-server-store-test");
        std::fs::create_dir_all(&dir).unwrap();
        let artifact = dir.join("batched.rmes");
        pack_compressed_model(&m, &cm.layers, 0.25, &artifact).unwrap();
        let reqs: Vec<Request> = (0..5)
            .map(|i| Request::Score {
                tokens: (0..6 + i).map(|t| ((t * (i + 2) + 3) % 32) as u32).collect(),
            })
            .collect();
        let one_expert = 32 * (2 * 16 + 1) * 4 + 16 * 4;
        for budget in [usize::MAX, 0, one_expert] {
            let mut serial = Engine::from_store(&artifact, budget).unwrap();
            serial.disable_prefetch();
            let want: Vec<Response> = reqs.iter().map(|r| serial.handle(r)).collect();
            let mut batched = Engine::from_store(&artifact, budget).unwrap();
            batched.disable_prefetch();
            let got = batched.handle_batch(&reqs);
            assert_eq!(got, want, "budget {budget}");
            let (ms, mb) = (
                serial.cache_metrics().unwrap(),
                batched.cache_metrics().unwrap(),
            );
            assert_eq!(ms.shard_fetches, mb.shard_fetches, "budget {budget}");
            assert_eq!(ms.shard_evictions, mb.shard_evictions, "budget {budget}");
            assert_eq!(ms.restore_serves, mb.restore_serves, "budget {budget}");
            assert_eq!(ms.fused_serves, mb.fused_serves, "budget {budget}");
        }
    }

    #[test]
    fn store_engine_pages_on_demand_without_full_decompression() {
        use crate::store::pack_compressed_model;
        let m = tiny_model(22);
        let mut rng = Rng::new(23);
        let cm = compress_model(&m, &ResMoE::up(), 0.25, 2, None, &mut rng);
        let dir = std::env::temp_dir().join("resmoe-server-store-test");
        std::fs::create_dir_all(&dir).unwrap();
        let artifact = dir.join("paging.rmes");
        pack_compressed_model(&m, &cm.layers, 0.25, &artifact).unwrap();
        let mut engine = Engine::from_store(&artifact, usize::MAX).unwrap();
        engine.disable_prefetch();
        let store = engine.backing_store().unwrap();
        let after_open = store.bytes_read();
        let resp = engine.handle(&Request::Score { tokens: vec![1, 5, 9, 2] });
        assert!(matches!(resp, Response::Score(_)), "{resp:?}");
        let served_read = store.bytes_read() - after_open;
        assert!(served_read > 0, "must have fetched at least one shard");
        // The serving path reads individual shards, never the whole file.
        assert!(
            store.bytes_read() < store.file_bytes(),
            "serving read {} of a {}-byte artifact — demand paging must not scan it all",
            store.bytes_read(),
            store.file_bytes()
        );
        let metrics = engine.cache_metrics().unwrap();
        assert!(metrics.shard_fetches > 0);
        assert!(
            (metrics.shard_fetches as usize) < 2 * 4,
            "4 tokens cannot demand every expert of every block"
        );
    }

    #[test]
    fn store_engine_prefetches_next_block_shards() {
        use crate::store::pack_compressed_model;
        // Four layers → MoE blocks 1 and 3, so block 1's routing predicts
        // block 3's demand.
        let mut cfg = ModelConfig::switch_mini(4);
        cfg.d_model = 16;
        cfg.d_inner = 32;
        cfg.n_layers = 4;
        cfg.n_heads = 2;
        cfg.vocab_size = 32;
        cfg.max_seq = 32;
        let mut rng = Rng::new(24);
        let m = Model::random(&cfg, &mut rng);
        let cm = compress_model(&m, &ResMoE::up(), 0.25, 2, None, &mut rng);
        assert_eq!(cm.layers.len(), 2);
        let dir = std::env::temp_dir().join("resmoe-server-store-test");
        std::fs::create_dir_all(&dir).unwrap();
        let artifact = dir.join("prefetch.rmes");
        pack_compressed_model(&m, &cm.layers, 0.25, &artifact).unwrap();
        let engine = Engine::from_store(&artifact, usize::MAX).unwrap();
        let resp = engine.handle(&Request::Score { tokens: vec![2, 7, 1, 9, 4, 3] });
        assert!(matches!(resp, Response::Score(_)), "{resp:?}");
        engine.quiesce_prefetch();
        let metrics = engine.cache_metrics().unwrap();
        assert!(
            metrics.prefetch_hits + metrics.prefetch_misses > 0,
            "serving across two compressed blocks must issue prefetch requests"
        );
    }

    #[test]
    fn stripped_engine_is_smaller_resident() {
        let m = tiny_model(8);
        let full_params = m.n_params();
        let mut rng = Rng::new(9);
        let cm = compress_model(&m, &ResMoE::up(), 0.25, 1, None, &mut rng);
        let engine = Engine::compressed(m, cm.layers, 0);
        assert!(engine.model().n_params() < full_params);
        let (compressed_bytes, cached) = engine.resident_expert_bytes().unwrap();
        assert!(compressed_bytes > 0);
        assert_eq!(cached, 0);
    }

    fn gen_reqs() -> Vec<Request> {
        vec![
            Request::Generate { prompt: vec![1, 2, 3], max_new: 1 },
            Request::Generate { prompt: vec![4, 5], max_new: 3 },
            Request::Generate { prompt: vec![6, 7, 8, 9], max_new: 2 },
            Request::Generate { prompt: vec![2, 2], max_new: 2 },
        ]
    }

    #[test]
    fn decode_run_batches_generates_and_matches_serial_under_roomy_budget() {
        // Under a roomy budget every slot restores on both sides, so the
        // relaxed contract collapses to bitwise equality: the batched
        // decode rows ARE the solo decode rows (pinned per-kernel in
        // moe::transformer), and the cost model makes the same decisions.
        let m = tiny_model(50);
        let mut rng = Rng::new(51);
        let cm = compress_model(&m, &ResMoE::up(), 0.25, 1, None, &mut rng);
        let reqs = gen_reqs();
        let serial = Engine::compressed(m.clone(), cm.layers.clone(), usize::MAX);
        let want: Vec<Response> = reqs.iter().map(|r| serial.handle(r)).collect();
        let mut batched = Engine::compressed(m.clone(), cm.layers.clone(), usize::MAX);
        // Cap the batch at 2 so retirements open slots for the pending
        // sequences — the continuous-batching join path, not just a
        // static batch.
        batched.set_decode_batch(2);
        let got = batched.handle_batch(&reqs);
        assert_eq!(got, want, "roomy budget: batched decode must equal serial bitwise");
        for r in &got {
            assert!(matches!(r, Response::Generate(_)), "{r:?}");
        }
        let dm = batched.decode_metrics();
        assert_eq!(dm.seqs, 4);
        assert!(dm.joins >= 1, "backfilled admissions must count as joins: {dm:?}");
        assert!(dm.steps > 0);
        assert!(dm.mean_step_batch() > 1.0, "{dm:?}");
        assert_eq!(dm.kv_leases, 4);
        assert_eq!(dm.kv_refusals, 0);
        assert_eq!(dm.solo_fallbacks, 0);
        let bm = batched.batch_metrics();
        assert_eq!(bm.batched_requests, 4);
        assert_eq!(bm.solo_requests, 0);
        // Every lease returned when its sequence retired.
        let pool = batched.kv_pool();
        assert_eq!(pool.used_bytes(), 0);
        assert_eq!(pool.leases_granted(), pool.leases_released());
    }

    #[test]
    fn decode_kv_refusal_falls_back_to_sequential_path() {
        // A zero budget gives the KV pool a zero cap: the first sequence
        // still enters (the single-over-budget exception guarantees
        // progress), every later admission is refused and served through
        // the sequential path instead. Nothing is revoked, nothing is
        // dropped, and with every serve fused (over budget) the outputs
        // are order-independent, so they still equal the serial reference.
        let m = tiny_model(52);
        let mut rng = Rng::new(53);
        let cm = compress_model(&m, &ResMoE::up(), 0.25, 1, None, &mut rng);
        let reqs = gen_reqs();
        let serial = Engine::compressed(m.clone(), cm.layers.clone(), 0);
        let want: Vec<Response> = reqs.iter().map(|r| serial.handle(r)).collect();
        let batched = Engine::compressed(m.clone(), cm.layers.clone(), 0);
        let got = batched.handle_batch(&reqs);
        assert_eq!(got, want, "all-fused serving is order-independent");
        let dm = batched.decode_metrics();
        assert_eq!(dm.kv_leases, 1, "only the over-budget exception admits: {dm:?}");
        assert_eq!(dm.kv_refusals, 3);
        assert_eq!(dm.solo_fallbacks, 3);
        assert_eq!(dm.seqs, 1);
        let bm = batched.batch_metrics();
        assert_eq!(bm.batched_requests, 1);
        assert_eq!(bm.solo_requests, 3);
        let pool = batched.kv_pool();
        assert_eq!(pool.used_bytes(), 0);
        assert_eq!(pool.refusals(), 3);
    }

    #[test]
    fn decode_batch_disabled_restores_serial_semantics() {
        // RESMOE_DECODE_BATCH=1 (set_decode_batch(1)) is the off-switch:
        // a window of Generates runs through the sequential path in
        // admission order — bit-for-bit the pre-batching behavior, even
        // under a tight budget where the interleaved order would diverge.
        let m = tiny_model(54);
        let mut rng = Rng::new(55);
        let cm = compress_model(&m, &ResMoE::up(), 0.25, 2, None, &mut rng);
        let one_expert = 32 * (2 * 16 + 1) * 4 + 16 * 4;
        let reqs = gen_reqs();
        for budget in [usize::MAX, 0, 2 * one_expert] {
            let serial = Engine::compressed(m.clone(), cm.layers.clone(), budget);
            let want: Vec<Response> = reqs.iter().map(|r| serial.handle(r)).collect();
            let mut off = Engine::compressed(m.clone(), cm.layers.clone(), budget);
            off.set_decode_batch(1);
            let got = off.handle_batch(&reqs);
            assert_eq!(got, want, "budget {budget}");
            let (ms, mo) = (
                serial.cache_metrics().unwrap(),
                off.cache_metrics().unwrap(),
            );
            assert_eq!(ms.misses, mo.misses, "budget {budget}");
            assert_eq!(ms.restore_serves, mo.restore_serves, "budget {budget}");
            assert_eq!(ms.fused_serves, mo.fused_serves, "budget {budget}");
            let dm = off.decode_metrics();
            assert_eq!(dm.steps, 0, "disabled decode batching must not step");
            assert_eq!(off.batch_metrics().solo_requests, 4);
        }
    }

    #[test]
    fn server_decode_lane_roundtrip_matches_serial() {
        // Generates submitted to the live server peel out of admission
        // windows into the per-worker decode lane. A dense engine has no
        // cost model, so lane answers are bit-identical to solo decoding
        // no matter how the steps interleave.
        let m = tiny_model(56);
        let reference = Engine::dense(m.clone());
        let reqs: Vec<Request> = (0..8)
            .map(|i| Request::Generate {
                prompt: (0..2 + (i % 3)).map(|t| ((t * 5 + i) % 32) as u32).collect(),
                max_new: 1 + (i % 4),
            })
            .collect();
        let want: Vec<Response> = reqs.iter().map(|r| reference.handle(r)).collect();
        let engine = Engine::dense(m);
        let server = Server::start(
            engine.clone(),
            ServerConfig { batch_max: 4, batch_wait_us: 200, workers: 1, ..Default::default() },
        );
        let replies: Vec<_> = reqs.iter().map(|r| server.submit(r.clone())).collect();
        // An invalid Generate never enters the lane: it stays in the
        // window and answers as an inline error.
        let bad = server.submit(Request::Generate { prompt: vec![], max_new: 3 });
        for (r, want) in replies.into_iter().zip(&want) {
            let (resp, latency) = r.recv().unwrap();
            assert_eq!(&resp, want);
            assert!(latency.as_secs() < 5);
        }
        assert!(matches!(bad.recv().unwrap().0, Response::Error(_)));
        let metrics = server.shutdown();
        assert_eq!(metrics.requests, 9);
        let dm = engine.decode_metrics();
        assert_eq!(dm.seqs, 8, "every valid Generate decodes through the lane");
        assert!(dm.steps > 0);
        assert_eq!(dm.kv_refusals, 0);
        let pool = engine.kv_pool();
        assert_eq!(pool.used_bytes(), 0, "all leases returned at retirement");
        assert_eq!(pool.leases_granted(), pool.leases_released());
    }
}
