//! The serving coordinator: request types, the cache-backed inference
//! engine (paper Alg. 2 on the hot path), a dynamic batcher, and a
//! thread-pool server. Pure std — no async runtime exists in the offline
//! vendor set, and a thread-per-worker loop over an mpsc queue is exactly
//! the right shape at this scale.

use super::batcher::next_batch;
use super::cache::{CacheMetrics, ExpertCache, Serve};
use super::metrics::ServerMetrics;
use crate::compress::{center_shared_act, fused_forward_expert, CompressedLayer, SharedAct};
use crate::moe::{route_dispatch_combine, Ffn, FfnHook, Model};
use crate::store::{ExpertStore, Prefetcher};
use crate::tensor::Matrix;
use crate::util::stats::logsumexp;
use anyhow::Result;
use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::Path;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

// The ExpertCache is internally synchronized (short metadata critical
// sections + per-key singleflight; see cache.rs module docs), so the engine
// shares it as a plain `Arc` — N workers overlap their store fetches,
// decodes, and restore matmuls instead of serializing on one cache mutex.

#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub batch_max: usize,
    pub batch_wait_us: u64,
    /// Byte budget for the restored-expert cache.
    pub cache_budget_bytes: usize,
    pub workers: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            batch_max: 8,
            batch_wait_us: 500,
            cache_budget_bytes: 64 * 1024 * 1024,
            workers: 2,
        }
    }
}

/// Inference requests.
#[derive(Debug, Clone)]
pub enum Request {
    /// Mean next-token log-prob of a sequence (scoring / PPL serving).
    Score { tokens: Vec<u32> },
    /// Greedy generation.
    Generate { prompt: Vec<u32>, max_new: usize },
    /// Classification through a stored task head.
    Classify { task: String, tokens: Vec<u32> },
}

impl Request {
    pub fn token_count(&self) -> u64 {
        match self {
            Request::Score { tokens } => tokens.len() as u64,
            Request::Generate { prompt, max_new } => (prompt.len() + max_new) as u64,
            Request::Classify { tokens, .. } => tokens.len() as u64,
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Score(f64),
    Generate(Vec<u32>),
    Classify(usize),
    Error(String),
}

/// The cache-backed engine: holds the backbone with compressed MoE blocks
/// *stripped of their dense experts* (only routers + shared experts stay
/// resident) plus the compressed representations and the restore cache.
/// In artifact mode ([`Engine::from_store`]) even the residuals live on
/// disk: the cache demand-pages individual expert shards and an async
/// prefetcher decodes router-predicted shards ahead of time.
#[derive(Clone)]
pub struct Engine {
    model: Arc<Model>,
    cache: Option<Arc<ExpertCache>>,
    prefetcher: Option<Arc<Prefetcher>>,
    /// block → next compressed block (the prefetch prediction target).
    next_block: Arc<HashMap<usize, usize>>,
}

impl Engine {
    /// Plain engine over a dense model (no compression).
    pub fn dense(model: Model) -> Engine {
        Engine {
            model: Arc::new(model),
            cache: None,
            prefetcher: None,
            next_block: Arc::new(HashMap::new()),
        }
    }

    /// Engine over compressed layers with a restore cache. `model` is the
    /// ORIGINAL (or restored) model; its compressed blocks are stripped.
    pub fn compressed(
        model: Model,
        layers: Vec<(usize, CompressedLayer)>,
        cache_budget_bytes: usize,
    ) -> Engine {
        let blocks: Vec<usize> = layers.iter().map(|(b, _)| *b).collect();
        let stripped = model.strip_experts(&blocks);
        Engine {
            model: Arc::new(stripped),
            cache: Some(Arc::new(ExpertCache::new(layers, cache_budget_bytes))),
            prefetcher: None,
            next_block: Arc::new(HashMap::new()),
        }
    }

    /// Construct-from-artifact: open an `RMES` store, load only the
    /// expert-stripped backbone + per-layer skeletons, and serve with
    /// demand-paged residual shards plus async prefetch. No full-file
    /// decompression happens here or later on the serving path.
    pub fn from_store(artifact: &Path, cache_budget_bytes: usize) -> Result<Engine> {
        let store = Arc::new(ExpertStore::open(artifact)?);
        let model = store.load_backbone()?;
        let cache = Arc::new(ExpertCache::from_store(store.clone(), cache_budget_bytes)?);
        let blocks = store.blocks();
        let mut next_block = HashMap::new();
        for w in blocks.windows(2) {
            next_block.insert(w[0], w[1]);
        }
        let prefetcher = Arc::new(Prefetcher::new(cache.clone(), store));
        Ok(Engine {
            model: Arc::new(model),
            cache: Some(cache),
            prefetcher: Some(prefetcher),
            next_block: Arc::new(next_block),
        })
    }

    /// Disable async prefetch on THIS engine handle (clones made earlier
    /// keep theirs) — determinism knob for tests and A/B benches.
    pub fn disable_prefetch(&mut self) {
        self.prefetcher = None;
        self.next_block = Arc::new(HashMap::new());
    }

    /// Block until in-flight prefetches land (deterministic metric reads).
    pub fn quiesce_prefetch(&self) {
        if let Some(pf) = &self.prefetcher {
            pf.quiesce();
        }
    }

    /// The backing artifact store, in [`Engine::from_store`] mode.
    pub fn backing_store(&self) -> Option<Arc<ExpertStore>> {
        self.cache.as_ref()?.backing_store().cloned()
    }

    pub fn model(&self) -> &Model {
        &self.model
    }

    pub fn cache_metrics(&self) -> Option<CacheMetrics> {
        self.cache.as_ref().map(|c| c.metrics())
    }

    /// Toggle the restore-free fused serve path (on by default; benches
    /// compare against the restore-only policy by switching it off).
    pub fn set_fused(&self, enabled: bool) {
        if let Some(c) = &self.cache {
            c.set_fused_enabled(enabled);
        }
    }

    pub fn resident_expert_bytes(&self) -> Option<(usize, usize)> {
        self.cache.as_ref().map(|c| (c.compressed_bytes(), c.used_bytes()))
    }

    /// (always-resident compressed bytes, restored dense bytes, paged shard
    /// bytes) — the three-way memory story of a store-backed deployment.
    pub fn resident_breakdown(&self) -> Option<(usize, usize, usize)> {
        self.cache
            .as_ref()
            .map(|c| (c.compressed_bytes(), c.used_bytes(), c.paged_bytes()))
    }

    fn hook(&self) -> EngineHook<'_> {
        EngineHook {
            model: &self.model,
            cache: self.cache.as_deref(),
            prefetcher: self.prefetcher.as_deref(),
            next_block: &self.next_block,
        }
    }

    pub fn handle(&self, req: &Request) -> Response {
        match req {
            Request::Score { tokens } => {
                if tokens.len() < 2 || tokens.len() > self.model.cfg.max_seq {
                    return Response::Error("score: need 2..=max_seq tokens".into());
                }
                let hook = self.hook();
                let h = self.model.hidden_states_hooked(tokens, None, &hook);
                let logits = h.matmul_nt(&self.model.lm_head);
                let mut total = 0.0f64;
                for i in 0..tokens.len() - 1 {
                    let row = logits.row(i);
                    total += (row[tokens[i + 1] as usize] - logsumexp(row)) as f64;
                }
                Response::Score(total / (tokens.len() - 1) as f64)
            }
            Request::Generate { prompt, max_new } => {
                if prompt.is_empty() || prompt.len() >= self.model.cfg.max_seq {
                    return Response::Error("generate: bad prompt length".into());
                }
                let hook = self.hook();
                let mut caches = self.model.fresh_caches();
                let mut logits = vec![0.0f32; self.model.cfg.vocab_size];
                for &t in prompt {
                    logits = self.model.decode_step_hooked(t, &mut caches, &hook);
                }
                let mut out = Vec::new();
                for _ in 0..*max_new {
                    if caches[0].len >= self.model.cfg.max_seq {
                        break;
                    }
                    let next = logits
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .map(|(i, _)| i as u32)
                        .unwrap();
                    out.push(next);
                    logits = self.model.decode_step_hooked(next, &mut caches, &hook);
                }
                Response::Generate(out)
            }
            Request::Classify { task, tokens } => {
                let Some(head) = self.model.head(task) else {
                    return Response::Error(format!("no head for task '{task}'"));
                };
                let head = head.clone();
                let hook = self.hook();
                let h = self.model.hidden_states_hooked(tokens, None, &hook);
                let logits = head.matvec(h.row(h.rows - 1));
                let pred = logits
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap();
                Response::Classify(pred)
            }
        }
    }
}

/// The FFN hook routing compressed blocks through the restore cache's
/// cost-model serve path: hot experts run dense from the cache, cold ones
/// run restore-free through the fused layer (monolithic mode) or the paged
/// center + single-expert pieces (store mode), with the center term
/// computed at most once per batch. In store mode the slots a block routed
/// to become the prefetch prediction for the next compressed block.
struct EngineHook<'a> {
    model: &'a Model,
    cache: Option<&'a ExpertCache>,
    prefetcher: Option<&'a Prefetcher>,
    next_block: &'a HashMap<usize, usize>,
}

impl FfnHook for EngineHook<'_> {
    fn ffn_forward(&self, block: usize, x: &Matrix) -> Option<Matrix> {
        let cache = self.cache?;
        let Ffn::Moe(layer) = &self.model.blocks[block].ffn else {
            return None;
        };
        if !cache.has_layer(block) {
            return None;
        }
        // Route with the resident router; serve each activated slot through
        // the cache's fused-vs-restore decision. The cache synchronizes
        // itself with short metadata critical sections and per-key
        // singleflight — fetches, decodes, restores, and every expert
        // forward here run without any global lock, so concurrent requests
        // overlap even while cold-missing (the Arc'd weights outlive the
        // cache's internal guards). The shared center term is built lazily
        // on the first fused slot and reused by the rest of the batch.
        let mut shared: Option<SharedAct> = None;
        let mut routed: Vec<usize> = Vec::new();
        let mut serve_error: Option<anyhow::Error> = None;
        let out = route_dispatch_combine(
            &layer.router,
            x,
            None,
            layer.shared_expert.as_ref(),
            |slot, sub, rows| {
                routed.push(slot);
                // try_serve so a store fetch/integrity error returns as a
                // value instead of panicking mid-dispatch; the error
                // surfaces below, after the combine finishes.
                let decision = cache.try_serve(block, slot, sub.rows);
                match decision {
                    Ok(Serve::Dense(expert)) => expert.forward(sub),
                    Ok(Serve::Fused(fl)) => {
                        let sh = shared.get_or_insert_with(|| fl.shared_act(x));
                        fl.forward_slot(slot, sub, &sh.gather(rows))
                    }
                    Ok(Serve::Paged { center, expert }) => {
                        let sh = shared.get_or_insert_with(|| center_shared_act(&center, x));
                        fused_forward_expert(&center, &expert, sub, &sh.gather(rows))
                    }
                    Err(e) => {
                        if serve_error.is_none() {
                            serve_error = Some(e);
                        }
                        Matrix::zeros(sub.rows, x.cols)
                    }
                }
            },
        );
        if let Some(e) = serve_error {
            // The panic fails THIS request (the server worker converts it
            // to Response::Error) and the cache stays healthy for the next
            // one. Never serve the zero-filled output.
            panic!("expert serve failed for block {block}: {e:#}");
        }
        // Router-predicted prefetch: expert choice is strongly correlated
        // across adjacent MoE blocks (upcycled experts in particular), so
        // the slots this block activated are the best zero-cost prediction
        // for the next compressed block. Fire-and-forget on the pool; the
        // cache lock is NOT held here.
        if let (Some(pf), Some(&nb)) = (self.prefetcher, self.next_block.get(&block)) {
            let keys: Vec<(usize, usize)> = routed.iter().map(|&s| (nb, s)).collect();
            pf.request(&keys);
        }
        Some(out)
    }
}

// ------------------------------------------------------------------ server

struct Job {
    req: Request,
    submitted: Instant,
    reply: Sender<(Response, Duration)>,
}

/// Thread-pool server with dynamic batching.
pub struct Server {
    tx: Option<Sender<Job>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    metrics: Arc<Mutex<ServerMetrics>>,
    started: Instant,
}

impl Server {
    pub fn start(engine: Engine, cfg: ServerConfig) -> Server {
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let metrics = Arc::new(Mutex::new(ServerMetrics::default()));
        let mut handles = Vec::new();
        for _ in 0..cfg.workers.max(1) {
            let rx = rx.clone();
            let engine = engine.clone();
            let metrics = metrics.clone();
            let wait = Duration::from_micros(cfg.batch_wait_us);
            let batch_max = cfg.batch_max.max(1);
            handles.push(std::thread::spawn(move || loop {
                // Hold the receiver lock only while draining one batch; the
                // actual compute runs unlocked so workers overlap.
                let batch = {
                    let guard = rx.lock().unwrap();
                    next_batch(&guard, batch_max, wait)
                };
                let Some(batch) = batch else { break };
                let mut tokens = 0u64;
                let size = batch.len();
                for job in batch {
                    tokens += job.req.token_count();
                    // A panic while serving (e.g. a corrupt artifact shard
                    // surfacing mid-request) must not take the worker down:
                    // answer THIS request with an error — carrying the panic
                    // message, so "checksum mismatch in block 3" reaches the
                    // client, not just stderr — and keep draining.
                    let resp = catch_unwind(AssertUnwindSafe(|| engine.handle(&job.req)))
                        .unwrap_or_else(|payload| {
                            let msg = payload
                                .downcast_ref::<&str>()
                                .map(|s| s.to_string())
                                .or_else(|| payload.downcast_ref::<String>().cloned())
                                .unwrap_or_else(|| "unknown panic".into());
                            Response::Error(format!("engine panicked while serving: {msg}"))
                        });
                    let latency = job.submitted.elapsed();
                    let _ = job.reply.send((resp, latency));
                    metrics.lock().unwrap().record_request(latency);
                }
                metrics.lock().unwrap().record_batch(size, tokens);
            }));
        }
        Server { tx: Some(tx), handles, metrics, started: Instant::now() }
    }

    /// Submit a request; the receiver yields (response, latency).
    pub fn submit(&self, req: Request) -> Receiver<(Response, Duration)> {
        let (reply_tx, reply_rx) = channel();
        let job = Job { req, submitted: Instant::now(), reply: reply_tx };
        self.tx.as_ref().expect("server running").send(job).expect("workers alive");
        reply_rx
    }

    /// Drain and stop, returning the aggregated metrics.
    pub fn shutdown(mut self) -> ServerMetrics {
        drop(self.tx.take());
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        let mut m = self.metrics.lock().unwrap().clone();
        m.wall_s = self.started.elapsed().as_secs_f64();
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{compress_model, ResMoE};
    use crate::moe::ModelConfig;
    use crate::util::Rng;

    fn tiny_model(seed: u64) -> Model {
        let mut cfg = ModelConfig::switch_mini(4);
        cfg.d_model = 16;
        cfg.d_inner = 32;
        cfg.n_layers = 2;
        cfg.n_heads = 2;
        cfg.vocab_size = 32;
        cfg.max_seq = 32;
        let mut rng = Rng::new(seed);
        Model::random(&cfg, &mut rng)
    }

    #[test]
    fn cached_engine_matches_restored_model() {
        // The serving hot path (lazy restore through the cache) must produce
        // EXACTLY the offline restored model's outputs.
        let m = tiny_model(1);
        let mut rng = Rng::new(2);
        let cm = compress_model(&m, &ResMoE::up(), 0.25, 1, None, &mut rng);
        let engine = Engine::compressed(m.clone(), cm.layers.clone(), usize::MAX);
        let tokens: Vec<u32> = vec![1, 5, 9, 2, 8, 3];
        let hook_out = match engine.handle(&Request::Score { tokens: tokens.clone() }) {
            Response::Score(s) => s,
            other => panic!("{other:?}"),
        };
        // Offline: fully restored model.
        let offline = Engine::dense(cm.model.clone());
        let want = match offline.handle(&Request::Score { tokens }) {
            Response::Score(s) => s,
            other => panic!("{other:?}"),
        };
        assert!((hook_out - want).abs() < 1e-5, "{hook_out} vs {want}");
    }

    #[test]
    fn generate_matches_restored_model() {
        let m = tiny_model(3);
        let mut rng = Rng::new(4);
        let cm = compress_model(&m, &ResMoE::up(), 0.25, 1, None, &mut rng);
        let engine = Engine::compressed(m.clone(), cm.layers.clone(), usize::MAX);
        let got = engine.handle(&Request::Generate { prompt: vec![1, 2, 3], max_new: 6 });
        let want = Response::Generate(cm.model.generate(&[1, 2, 3], 6));
        assert_eq!(got, want);
    }

    #[test]
    fn thrashed_engine_serves_fused_and_matches_restored_model() {
        // Budget below one restored expert: every MoE block runs restore-
        // free, and the score must still equal the offline restored model.
        let m = tiny_model(10);
        let mut rng = Rng::new(11);
        let cm = compress_model(&m, &ResMoE::up(), 0.25, 2, None, &mut rng);
        let expert_bytes = 0; // force thrash with a zero budget
        let engine = Engine::compressed(m.clone(), cm.layers.clone(), expert_bytes);
        let tokens: Vec<u32> = vec![2, 7, 1, 9, 4, 3, 8];
        let got = match engine.handle(&Request::Score { tokens: tokens.clone() }) {
            Response::Score(s) => s,
            other => panic!("{other:?}"),
        };
        let offline = Engine::dense(cm.model.clone());
        let want = match offline.handle(&Request::Score { tokens }) {
            Response::Score(s) => s,
            other => panic!("{other:?}"),
        };
        assert!((got - want).abs() < 1e-5, "{got} vs {want}");
        let metrics = engine.cache_metrics().unwrap();
        assert!(metrics.fused_serves > 0, "thrash budget must use the fused path");
        assert_eq!(metrics.restore_serves, 0);
        // Restore-only policy agrees numerically (A/B switch).
        let engine_restore = Engine::compressed(m, cm.layers, expert_bytes);
        engine_restore.set_fused(false);
        let got_restore =
            match engine_restore.handle(&Request::Score { tokens: vec![2, 7, 1, 9, 4, 3, 8] }) {
                Response::Score(s) => s,
                other => panic!("{other:?}"),
            };
        assert!((got_restore - want).abs() < 1e-5);
        let m2 = engine_restore.cache_metrics().unwrap();
        assert_eq!(m2.fused_serves, 0);
        assert!(m2.restore_serves > 0);
    }

    #[test]
    fn error_responses() {
        let engine = Engine::dense(tiny_model(5));
        assert!(matches!(
            engine.handle(&Request::Score { tokens: vec![1] }),
            Response::Error(_)
        ));
        assert!(matches!(
            engine.handle(&Request::Classify { task: "none".into(), tokens: vec![1, 2] }),
            Response::Error(_)
        ));
    }

    #[test]
    fn server_roundtrip_under_load() {
        let m = tiny_model(6);
        let mut rng = Rng::new(7);
        let cm = compress_model(&m, &ResMoE::up(), 0.25, 1, None, &mut rng);
        let engine = Engine::compressed(m, cm.layers, 1 << 20);
        let server = Server::start(
            engine,
            ServerConfig { batch_max: 4, batch_wait_us: 200, workers: 2, ..Default::default() },
        );
        let replies: Vec<_> = (0..16)
            .map(|i| {
                server.submit(Request::Score {
                    tokens: (0..8).map(|t| ((t + i) % 32) as u32).collect(),
                })
            })
            .collect();
        for r in replies {
            let (resp, latency) = r.recv().unwrap();
            assert!(matches!(resp, Response::Score(_)), "{resp:?}");
            assert!(latency.as_secs() < 5);
        }
        let metrics = server.shutdown();
        assert_eq!(metrics.latencies_s.len(), 16);
        assert!(metrics.mean_batch() >= 1.0);
    }

    #[test]
    fn store_engine_matches_monolithic_engine_bit_for_bit() {
        // Pack → serve must equal the monolithic-load engine EXACTLY: the
        // shard codec round-trips f32 bits, the cost model sees identical
        // dense occupancy in both modes, and the paged fused path runs the
        // same arithmetic as the monolithic fused path.
        use crate::store::pack_compressed_model;
        let m = tiny_model(20);
        let mut rng = Rng::new(21);
        let cm = compress_model(&m, &ResMoE::up(), 0.25, 2, None, &mut rng);
        let dir = std::env::temp_dir().join("resmoe-server-store-test");
        std::fs::create_dir_all(&dir).unwrap();
        let artifact = dir.join("engine.rmes");
        pack_compressed_model(&m, &cm.layers, 0.25, &artifact).unwrap();
        let reqs: Vec<Request> = (0..6)
            .map(|i| Request::Score {
                tokens: (0..10).map(|t| ((t * (i + 3) + 1) % 32) as u32).collect(),
            })
            .collect();
        // Same budgets → same decisions → identical outputs, across warm,
        // thrash, and tight budgets.
        let one_expert = 32 * (2 * 16 + 1) * 4 + 16 * 4; // pi*(2p+1)+p floats
        for budget in [usize::MAX, 0, one_expert, 2 * one_expert] {
            let mono = Engine::compressed(m.clone(), cm.layers.clone(), budget);
            let mut packed = Engine::from_store(&artifact, budget).unwrap();
            packed.disable_prefetch(); // deterministic decision sequence
            for req in &reqs {
                let a = mono.handle(req);
                let b = packed.handle(req);
                assert_eq!(a, b, "budget {budget}: packed engine must match exactly");
            }
        }
    }

    #[test]
    fn store_engine_pages_on_demand_without_full_decompression() {
        use crate::store::pack_compressed_model;
        let m = tiny_model(22);
        let mut rng = Rng::new(23);
        let cm = compress_model(&m, &ResMoE::up(), 0.25, 2, None, &mut rng);
        let dir = std::env::temp_dir().join("resmoe-server-store-test");
        std::fs::create_dir_all(&dir).unwrap();
        let artifact = dir.join("paging.rmes");
        pack_compressed_model(&m, &cm.layers, 0.25, &artifact).unwrap();
        let mut engine = Engine::from_store(&artifact, usize::MAX).unwrap();
        engine.disable_prefetch();
        let store = engine.backing_store().unwrap();
        let after_open = store.bytes_read();
        let resp = engine.handle(&Request::Score { tokens: vec![1, 5, 9, 2] });
        assert!(matches!(resp, Response::Score(_)), "{resp:?}");
        let served_read = store.bytes_read() - after_open;
        assert!(served_read > 0, "must have fetched at least one shard");
        // The serving path reads individual shards, never the whole file.
        assert!(
            store.bytes_read() < store.file_bytes(),
            "serving read {} of a {}-byte artifact — demand paging must not scan it all",
            store.bytes_read(),
            store.file_bytes()
        );
        let metrics = engine.cache_metrics().unwrap();
        assert!(metrics.shard_fetches > 0);
        assert!(
            (metrics.shard_fetches as usize) < 2 * 4,
            "4 tokens cannot demand every expert of every block"
        );
    }

    #[test]
    fn store_engine_prefetches_next_block_shards() {
        use crate::store::pack_compressed_model;
        // Four layers → MoE blocks 1 and 3, so block 1's routing predicts
        // block 3's demand.
        let mut cfg = ModelConfig::switch_mini(4);
        cfg.d_model = 16;
        cfg.d_inner = 32;
        cfg.n_layers = 4;
        cfg.n_heads = 2;
        cfg.vocab_size = 32;
        cfg.max_seq = 32;
        let mut rng = Rng::new(24);
        let m = Model::random(&cfg, &mut rng);
        let cm = compress_model(&m, &ResMoE::up(), 0.25, 2, None, &mut rng);
        assert_eq!(cm.layers.len(), 2);
        let dir = std::env::temp_dir().join("resmoe-server-store-test");
        std::fs::create_dir_all(&dir).unwrap();
        let artifact = dir.join("prefetch.rmes");
        pack_compressed_model(&m, &cm.layers, 0.25, &artifact).unwrap();
        let engine = Engine::from_store(&artifact, usize::MAX).unwrap();
        let resp = engine.handle(&Request::Score { tokens: vec![2, 7, 1, 9, 4, 3] });
        assert!(matches!(resp, Response::Score(_)), "{resp:?}");
        engine.quiesce_prefetch();
        let metrics = engine.cache_metrics().unwrap();
        assert!(
            metrics.prefetch_hits + metrics.prefetch_misses > 0,
            "serving across two compressed blocks must issue prefetch requests"
        );
    }

    #[test]
    fn stripped_engine_is_smaller_resident() {
        let m = tiny_model(8);
        let full_params = m.n_params();
        let mut rng = Rng::new(9);
        let cm = compress_model(&m, &ResMoE::up(), 0.25, 1, None, &mut rng);
        let engine = Engine::compressed(m, cm.layers, 0);
        assert!(engine.model().n_params() < full_params);
        let (compressed_bytes, cached) = engine.resident_expert_bytes().unwrap();
        assert!(compressed_bytes > 0);
        assert_eq!(cached, 0);
    }
}
