//! Serving metrics: latency percentiles, throughput, batch-size histogram,
//! the continuous-batching window/occupancy story, and the cache/paging
//! summary line.
//!
//! Since PR 7 the live counters behind these summaries are lock-free
//! [`crate::obs`] instruments ([`ServerStats`], [`BatchCounters`], and the
//! cache's own counter set): recording is a few relaxed atomic adds, and
//! the plain structs here ([`ServerMetrics`], [`BatchMetrics`]) are
//! point-in-time snapshots of those instruments. The summary-line formats
//! are pinned by golden tests below so dashboard/CI parsers don't silently
//! break as counters migrate.

use super::batcher::FlushReason;
use super::cache::CacheMetrics;
use crate::obs::{Counter, Histogram, HistogramSnapshot, Registry};
use std::sync::Arc;
use std::time::Duration;

/// Point-in-time server metrics snapshot. Latency and batch-size live in
/// bounded log-linear histograms (O(1) record, fixed memory) instead of the
/// pre-PR-7 unbounded `Vec<f64>` that was re-sorted on every percentile
/// read; quantiles are conservative bucket upper bounds with ≤ 1/16
/// relative error.
#[derive(Debug, Default, Clone)]
pub struct ServerMetrics {
    /// Requests completed (and measured into `latency_us`).
    pub requests: u64,
    /// Per-request latency histogram, microseconds.
    pub latency_us: HistogramSnapshot,
    /// Executed-window size histogram (sizes < 16 are exact buckets).
    pub batch_size: HistogramSnapshot,
    pub tokens_processed: u64,
    /// Requests shed by admission control or deadline enforcement
    /// (answered [`super::server::Response::Overloaded`], never executed).
    pub shed: u64,
    pub wall_s: f64,
}

impl ServerMetrics {
    pub fn p50_ms(&self) -> f64 {
        self.latency_us.quantile(0.5) as f64 / 1e3
    }

    pub fn p99_ms(&self) -> f64 {
        self.latency_us.quantile(0.99) as f64 / 1e3
    }

    /// Mean executed-window size. Exact (the histogram keeps an exact sum
    /// and count) even though quantiles are bucketed.
    pub fn mean_batch(&self) -> f64 {
        self.batch_size.mean()
    }

    pub fn requests_per_s(&self) -> f64 {
        if self.wall_s <= 0.0 {
            0.0
        } else {
            self.requests as f64 / self.wall_s
        }
    }

    pub fn tokens_per_s(&self) -> f64 {
        if self.wall_s <= 0.0 {
            0.0
        } else {
            self.tokens_processed as f64 / self.wall_s
        }
    }

    pub fn summary(&self) -> String {
        let mut line = format!(
            "{} requests | {:.1} req/s | {:.0} tok/s | p50 {:.2} ms | p99 {:.2} ms | mean batch {:.1}",
            self.requests,
            self.requests_per_s(),
            self.tokens_per_s(),
            self.p50_ms(),
            self.p99_ms(),
            self.mean_batch()
        );
        if self.shed > 0 {
            line.push_str(&format!(" | {} shed", self.shed));
        }
        line
    }
}

/// Live, lock-free server instruments registered as `server.*` on the
/// engine's [`Registry`]. The worker loop records into these from any
/// thread without a mutex (the pre-PR-7 `Arc<Mutex<ServerMetrics>>` made
/// every request completion a lock acquisition); [`ServerStats::snapshot`]
/// materializes the plain [`ServerMetrics`] view.
#[derive(Clone)]
pub struct ServerStats {
    pub requests: Arc<Counter>,
    pub tokens: Arc<Counter>,
    pub batches: Arc<Counter>,
    pub shed: Arc<Counter>,
    pub latency_us: Arc<Histogram>,
    pub batch_size: Arc<Histogram>,
}

impl ServerStats {
    pub fn new(reg: &Registry) -> ServerStats {
        ServerStats {
            requests: reg.counter("server.requests"),
            tokens: reg.counter("server.tokens"),
            batches: reg.counter("server.batches"),
            shed: reg.counter("server.shed"),
            latency_us: reg.histogram("server.latency_us"),
            batch_size: reg.histogram("server.batch_size"),
        }
    }

    pub fn record_request(&self, latency: Duration) {
        self.requests.inc();
        self.latency_us.record(latency.as_micros() as u64);
    }

    /// Record one request shed by admission control or a missed deadline.
    pub fn record_shed(&self) {
        self.shed.inc();
    }

    pub fn record_batch(&self, size: usize, tokens: u64) {
        self.batches.inc();
        self.batch_size.record(size as u64);
        self.tokens.add(tokens);
    }

    pub fn snapshot(&self, wall_s: f64) -> ServerMetrics {
        ServerMetrics {
            requests: self.requests.get(),
            latency_us: self.latency_us.snapshot(),
            batch_size: self.batch_size.snapshot(),
            tokens_processed: self.tokens.get(),
            shed: self.shed.get(),
            wall_s,
        }
    }
}

/// Histogram buckets shared by the occupancy and rows-per-expert
/// histograms: 1, 2, 3–4, 5–8, >8.
pub const BATCH_BUCKETS: [&str; 5] = ["1", "2", "3-4", "5-8", ">8"];

/// Registry-name suffixes for [`BATCH_BUCKETS`] (metric names stay
/// alphanumeric so the Prometheus mangling is readable).
const BUCKET_NAMES: [&str; 5] = ["b1", "b2", "b3_4", "b5_8", "gt8"];

fn bucket_of(n: usize) -> usize {
    match n {
        0 | 1 => 0,
        2 => 1,
        3..=4 => 2,
        5..=8 => 3,
        _ => 4,
    }
}

/// Continuous-batching counters: how windows form (occupancy, flush
/// reasons, linger) and how much cross-request row sharing each expert
/// dispatch actually sees. Recorded by `Engine::handle_batch` and the
/// batched FFN hook; surfaced through [`batch_summary`] so the counters
/// can't silently rot (a unit test pins the line's contents).
#[derive(Debug, Default, Clone)]
pub struct BatchMetrics {
    /// Batch windows executed end-to-end (one `Engine::handle_batch` call).
    pub windows: u64,
    /// Requests that shared a multi-request batched prefill run.
    pub batched_requests: u64,
    /// Requests served alone: windows of one, sequential (generate)
    /// requests, and invalid requests answered without a forward.
    pub solo_requests: u64,
    /// Window flush reasons (from the admission queue; direct
    /// `handle_batch` calls don't record one).
    pub full_flushes: u64,
    pub linger_flushes: u64,
    pub closed_flushes: u64,
    /// Total µs flushed windows' oldest requests lingered. Mean = divided
    /// by the flush count (full + linger + closed), NOT by `windows` —
    /// direct `handle_batch` calls record a window but no flush.
    pub linger_us: u64,
    /// Window occupancy histogram over [`BATCH_BUCKETS`].
    pub occupancy: [u64; 5],
    /// Rows-per-expert-dispatch histogram over [`BATCH_BUCKETS`] — the
    /// direct measure of how much work concatenation fuses per expert.
    pub rows_per_expert: [u64; 5],
    /// Expert dispatch calls and their total rows (mean rows/dispatch).
    pub expert_dispatches: u64,
    pub expert_rows: u64,
}

impl BatchMetrics {
    /// Record one executed window of `size` requests.
    pub fn record_window(&mut self, size: usize) {
        self.windows += 1;
        self.occupancy[bucket_of(size)] += 1;
    }

    /// Record the admission-queue flush that produced a window.
    pub fn record_flush(&mut self, reason: FlushReason, waited_us: u64) {
        match reason {
            FlushReason::Full => self.full_flushes += 1,
            FlushReason::Linger => self.linger_flushes += 1,
            FlushReason::Closed => self.closed_flushes += 1,
        }
        self.linger_us += waited_us;
    }

    /// Record one expert dispatch over `rows` concatenated rows.
    pub fn record_dispatch(&mut self, rows: usize) {
        self.expert_dispatches += 1;
        self.expert_rows += rows as u64;
        self.rows_per_expert[bucket_of(rows)] += 1;
    }

    pub fn mean_occupancy(&self) -> f64 {
        if self.windows == 0 {
            0.0
        } else {
            (self.batched_requests + self.solo_requests) as f64 / self.windows as f64
        }
    }

    pub fn mean_rows_per_dispatch(&self) -> f64 {
        if self.expert_dispatches == 0 {
            0.0
        } else {
            self.expert_rows as f64 / self.expert_dispatches as f64
        }
    }

    pub fn mean_linger_us(&self) -> f64 {
        let flushes = self.full_flushes + self.linger_flushes + self.closed_flushes;
        if flushes == 0 {
            0.0
        } else {
            self.linger_us as f64 / flushes as f64
        }
    }
}

/// Atomic twins of every [`BatchMetrics`] field, registered as `batch.*`
/// instruments. The engine records into these lock-free from the batched
/// FFN hook (which runs inside the forward pass — pre-PR-7 this was a
/// `Mutex<BatchMetrics>` acquisition per window *and* per expert
/// dispatch); [`BatchCounters::snapshot`] materializes the plain struct
/// for `batch_summary`.
pub struct BatchCounters {
    pub windows: Arc<Counter>,
    pub batched_requests: Arc<Counter>,
    pub solo_requests: Arc<Counter>,
    pub full_flushes: Arc<Counter>,
    pub linger_flushes: Arc<Counter>,
    pub closed_flushes: Arc<Counter>,
    pub linger_us: Arc<Counter>,
    pub occupancy: [Arc<Counter>; 5],
    pub rows_per_expert: [Arc<Counter>; 5],
    pub expert_dispatches: Arc<Counter>,
    pub expert_rows: Arc<Counter>,
}

impl BatchCounters {
    pub fn new(reg: &Registry) -> BatchCounters {
        let family = |prefix: &str| -> [Arc<Counter>; 5] {
            BUCKET_NAMES.map(|b| reg.counter(&format!("{prefix}.{b}")))
        };
        BatchCounters {
            windows: reg.counter("batch.windows"),
            batched_requests: reg.counter("batch.batched_requests"),
            solo_requests: reg.counter("batch.solo_requests"),
            full_flushes: reg.counter("batch.full_flushes"),
            linger_flushes: reg.counter("batch.linger_flushes"),
            closed_flushes: reg.counter("batch.closed_flushes"),
            linger_us: reg.counter("batch.linger_us"),
            occupancy: family("batch.occupancy"),
            rows_per_expert: family("batch.rows_per_expert"),
            expert_dispatches: reg.counter("batch.expert_dispatches"),
            expert_rows: reg.counter("batch.expert_rows"),
        }
    }

    /// Record one executed window of `size` requests.
    pub fn record_window(&self, size: usize) {
        self.windows.inc();
        self.occupancy[bucket_of(size)].inc();
    }

    /// Record the admission-queue flush that produced a window.
    pub fn record_flush(&self, reason: FlushReason, waited_us: u64) {
        match reason {
            FlushReason::Full => self.full_flushes.inc(),
            FlushReason::Linger => self.linger_flushes.inc(),
            FlushReason::Closed => self.closed_flushes.inc(),
        }
        self.linger_us.add(waited_us);
    }

    /// Record one expert dispatch over `rows` concatenated rows.
    pub fn record_dispatch(&self, rows: usize) {
        self.expert_dispatches.inc();
        self.expert_rows.add(rows as u64);
        self.rows_per_expert[bucket_of(rows)].inc();
    }

    /// Read every counter into the plain snapshot struct (relaxed loads,
    /// no lock).
    pub fn snapshot(&self) -> BatchMetrics {
        let read = |f: &[Arc<Counter>; 5]| -> [u64; 5] {
            [f[0].get(), f[1].get(), f[2].get(), f[3].get(), f[4].get()]
        };
        BatchMetrics {
            windows: self.windows.get(),
            batched_requests: self.batched_requests.get(),
            solo_requests: self.solo_requests.get(),
            full_flushes: self.full_flushes.get(),
            linger_flushes: self.linger_flushes.get(),
            closed_flushes: self.closed_flushes.get(),
            linger_us: self.linger_us.get(),
            occupancy: read(&self.occupancy),
            rows_per_expert: read(&self.rows_per_expert),
            expert_dispatches: self.expert_dispatches.get(),
            expert_rows: self.expert_rows.get(),
        }
    }
}

/// Point-in-time decode-lane snapshot: how the iteration-level decode
/// batch formed (admissions, mid-flight joins, step occupancy) and how the
/// KV page pool behaved (leases, refusals, serial fallbacks).
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct DecodeMetrics {
    /// Batched decode model steps executed (one layer-major forward over
    /// all active sequences).
    pub steps: u64,
    /// Tokens fed across all steps (Σ step batch sizes).
    pub tokens: u64,
    /// Sequences admitted into a decode batch.
    pub seqs: u64,
    /// Sequences that joined while at least one other sequence was
    /// mid-generation — the continuous-batching admissions.
    pub joins: u64,
    /// KV page-pool leases granted / refused. A refusal never fails the
    /// request; it falls back to the serial decode path (`solo_fallbacks`).
    pub kv_leases: u64,
    pub kv_refusals: u64,
    pub solo_fallbacks: u64,
}

impl DecodeMetrics {
    /// Mean sequences per decode step — the decode analog of window
    /// occupancy; the throughput multiplier over serial decode.
    pub fn mean_step_batch(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.tokens as f64 / self.steps as f64
        }
    }
}

/// Lock-free `decode.*` twins of [`DecodeMetrics`], registered
/// unconditionally at engine construction so every tenant snapshot exports
/// the same instrument schema whether or not decode traffic arrived.
pub struct DecodeCounters {
    pub steps: Arc<Counter>,
    pub tokens: Arc<Counter>,
    pub seqs: Arc<Counter>,
    pub joins: Arc<Counter>,
    pub kv_leases: Arc<Counter>,
    pub kv_refusals: Arc<Counter>,
    pub solo_fallbacks: Arc<Counter>,
    /// Step batch-size histogram (sequences per batched decode step).
    pub step_batch: Arc<Histogram>,
}

impl DecodeCounters {
    pub fn new(reg: &Registry) -> DecodeCounters {
        DecodeCounters {
            steps: reg.counter("decode.steps"),
            tokens: reg.counter("decode.tokens"),
            seqs: reg.counter("decode.seqs"),
            joins: reg.counter("decode.joins"),
            kv_leases: reg.counter("decode.kv_leases"),
            kv_refusals: reg.counter("decode.kv_refusals"),
            solo_fallbacks: reg.counter("decode.solo_fallbacks"),
            step_batch: reg.histogram("decode.step_batch"),
        }
    }

    /// Record one batched decode step over `batch` active sequences.
    pub fn record_step(&self, batch: usize) {
        self.steps.inc();
        self.tokens.add(batch as u64);
        self.step_batch.record(batch as u64);
    }

    pub fn snapshot(&self) -> DecodeMetrics {
        DecodeMetrics {
            steps: self.steps.get(),
            tokens: self.tokens.get(),
            seqs: self.seqs.get(),
            joins: self.joins.get(),
            kv_leases: self.kv_leases.get(),
            kv_refusals: self.kv_refusals.get(),
            solo_fallbacks: self.solo_fallbacks.get(),
        }
    }
}

/// One-line decode-lane story. Separate from [`batch_summary`] so the
/// golden prefill-batching format stays byte-stable; quiet segments only
/// appear once the lane has actually seen traffic.
pub fn decode_summary(dm: &DecodeMetrics) -> String {
    let mut line = format!(
        "decode: {} steps | {:.2} mean step batch | {} seqs ({} joins)",
        dm.steps,
        dm.mean_step_batch(),
        dm.seqs,
        dm.joins,
    );
    if dm.kv_leases + dm.kv_refusals > 0 {
        line.push_str(&format!(
            " | kv: {} leases, {} refusals, {} solo fallbacks",
            dm.kv_leases, dm.kv_refusals, dm.solo_fallbacks
        ));
    }
    line
}

/// One-line continuous-batching story — the `cache_summary` analog for the
/// window scheduler: occupancy, flush split, linger, and per-expert row
/// fusion.
pub fn batch_summary(bm: &BatchMetrics) -> String {
    let hist = |h: &[u64; 5]| -> String {
        BATCH_BUCKETS
            .iter()
            .zip(h)
            .map(|(b, c)| format!("{b}:{c}"))
            .collect::<Vec<_>>()
            .join(" ")
    };
    let mut line = format!(
        "batch: {} windows | {:.2} mean occupancy [{}] | {} batched / {} solo requests",
        bm.windows,
        bm.mean_occupancy(),
        hist(&bm.occupancy),
        bm.batched_requests,
        bm.solo_requests,
    );
    if bm.full_flushes + bm.linger_flushes + bm.closed_flushes > 0 {
        line.push_str(&format!(
            " | flushes {} full / {} linger / {} closed, {:.0} us mean linger",
            bm.full_flushes,
            bm.linger_flushes,
            bm.closed_flushes,
            bm.mean_linger_us(),
        ));
    }
    if bm.expert_dispatches > 0 {
        line.push_str(&format!(
            " | {:.2} rows/expert dispatch [{}]",
            bm.mean_rows_per_dispatch(),
            hist(&bm.rows_per_expert),
        ));
    }
    line
}

/// One-line cache/paging story for demo + CLI output: hit rate, the
/// fused-vs-restore decision split, shard paging traffic, and prefetch
/// effectiveness.
pub fn cache_summary(cm: &CacheMetrics) -> String {
    let mut line = format!(
        "cache: {:.1} % hit rate | {} restores / {} fused serves | {} evictions",
        cm.hit_rate() * 100.0,
        cm.restore_serves,
        cm.fused_serves,
        cm.evictions
    );
    if cm.shard_fetches > 0 {
        line.push_str(&format!(
            " | {} shard fetches ({:.2} ms, {} decoded), {} shard evictions",
            cm.shard_fetches,
            cm.shard_fetch_ns as f64 / 1e6,
            crate::util::format_bytes(cm.shard_bytes as usize),
            cm.shard_evictions
        ));
    }
    if cm.prefetch_hits + cm.prefetch_misses > 0 {
        line.push_str(&format!(
            " | prefetch: {} hits / {} loads, {:.0} % useful, {} dropped",
            cm.prefetch_hits,
            cm.prefetch_misses,
            cm.prefetch_usefulness() * 100.0,
            cm.prefetch_dropped
        ));
    }
    if cm.singleflight_waits + cm.dedup_fetches + cm.publish_races_lost > 0 {
        line.push_str(&format!(
            " | singleflight: {} waits, {} deduped, {} publish races lost",
            cm.singleflight_waits, cm.dedup_fetches, cm.publish_races_lost
        ));
    }
    // The fault-tolerance story stays invisible until something actually
    // goes wrong — a healthy run's summary line is byte-identical to the
    // pre-fault-tolerance format (pinned by the golden test below).
    if cm.transient_errors
        + cm.fetch_retries
        + cm.quarantined_shards
        + cm.degraded_serves
        + cm.prefetch_errors
        > 0
    {
        line.push_str(&format!(
            " | faults: {} transient, {} retries, {} quarantines, {} degraded, {} prefetch errors",
            cm.transient_errors,
            cm.fetch_retries,
            cm.quarantined_shards,
            cm.degraded_serves,
            cm.prefetch_errors
        ));
    }
    line
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_and_rates() {
        let reg = Registry::new();
        let stats = ServerStats::new(&reg);
        for i in 1..=100u64 {
            stats.record_request(Duration::from_millis(i));
        }
        stats.record_batch(4, 400);
        stats.record_batch(8, 800);
        let m = stats.snapshot(2.0);
        assert_eq!(m.requests, 100);
        // Histogram quantiles are conservative bucket upper bounds:
        // within +1/16 of the exact percentile, never below it.
        let p50 = m.p50_ms();
        assert!(p50 >= 50.0 && p50 <= 50.0 * (1.0 + 1.0 / 16.0) + 0.1, "p50={p50}");
        let p99 = m.p99_ms();
        assert!(p99 >= 99.0 && p99 <= 99.0 * (1.0 + 1.0 / 16.0) + 0.1, "p99={p99}");
        assert_eq!(m.mean_batch(), 6.0);
        assert_eq!(m.requests_per_s(), 50.0);
        assert_eq!(m.tokens_per_s(), 600.0);
        assert!(!m.summary().is_empty());
        // The instruments are visible to a registry snapshot under the
        // same names the rest of the stack exports.
        let snap = reg.snapshot();
        assert_eq!(snap.counter("server.requests"), Some(100));
        assert_eq!(snap.counter("server.tokens"), Some(1200));
        assert_eq!(snap.histogram("server.latency_us").unwrap().count, 100);
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = ServerMetrics::default();
        assert_eq!(m.p50_ms(), 0.0);
        assert_eq!(m.mean_batch(), 0.0);
        assert_eq!(m.requests_per_s(), 0.0);
    }

    #[test]
    fn batch_summary_surfaces_every_counter_family() {
        let mut bm = BatchMetrics::default();
        // Quiet engine: windows only.
        bm.record_window(1);
        bm.solo_requests += 1;
        let quiet = batch_summary(&bm);
        assert!(quiet.contains("1 windows"));
        assert!(quiet.contains("[1:1 2:0 3-4:0 5-8:0 >8:0]"));
        assert!(!quiet.contains("flushes"), "no queue flushes recorded yet");
        assert!(!quiet.contains("dispatch"), "no expert dispatches recorded yet");
        // A busy window: occupancy 4, full flush after 120 us, two expert
        // dispatches fusing 4 + 9 rows.
        bm.record_window(4);
        bm.batched_requests += 4;
        bm.record_flush(FlushReason::Full, 120);
        bm.record_flush(FlushReason::Linger, 480);
        bm.record_dispatch(4);
        bm.record_dispatch(9);
        assert_eq!(bm.occupancy, [1, 0, 1, 0, 0]);
        assert_eq!(bm.rows_per_expert, [0, 0, 1, 0, 1]);
        assert!((bm.mean_occupancy() - 2.5).abs() < 1e-9);
        assert!((bm.mean_rows_per_dispatch() - 6.5).abs() < 1e-9);
        assert!((bm.mean_linger_us() - 300.0).abs() < 1e-9);
        let busy = batch_summary(&bm);
        assert!(busy.contains("2 windows"));
        assert!(busy.contains("flushes 1 full / 1 linger / 0 closed"));
        assert!(busy.contains("300 us mean linger"));
        assert!(busy.contains("6.50 rows/expert dispatch"));
        assert!(busy.contains("3-4:1 5-8:0 >8:1"), "{busy}");
    }

    #[test]
    fn batch_counters_snapshot_matches_plain_recording() {
        // The atomic twin and the plain struct, driven by the same event
        // sequence, must produce identical snapshots — this is what lets
        // the engine migrate to lock-free recording without perturbing a
        // single summary line.
        let reg = Registry::new();
        let bc = BatchCounters::new(&reg);
        let mut bm = BatchMetrics::default();
        for (size, rows) in [(1usize, 3usize), (4, 9), (2, 1)] {
            bc.record_window(size);
            bm.record_window(size);
            bc.record_dispatch(rows);
            bm.record_dispatch(rows);
        }
        bc.record_flush(FlushReason::Full, 120);
        bm.record_flush(FlushReason::Full, 120);
        bc.record_flush(FlushReason::Closed, 40);
        bm.record_flush(FlushReason::Closed, 40);
        bc.batched_requests.add(5);
        bm.batched_requests += 5;
        bc.solo_requests.add(2);
        bm.solo_requests += 2;
        assert_eq!(format!("{:?}", bc.snapshot()), format!("{bm:?}"));
        assert_eq!(batch_summary(&bc.snapshot()), batch_summary(&bm));
        // And the counters are addressable through the registry.
        let snap = reg.snapshot();
        assert_eq!(snap.counter("batch.windows"), Some(3));
        assert_eq!(snap.counter("batch.occupancy.b3_4"), Some(1));
        assert_eq!(snap.counter("batch.rows_per_expert.gt8"), Some(1));
    }

    #[test]
    fn decode_counters_snapshot_and_summary() {
        let reg = Registry::new();
        let dc = DecodeCounters::new(&reg);
        // Quiet lane: zero everything, no kv segment.
        let quiet = decode_summary(&dc.snapshot());
        assert_eq!(quiet, "decode: 0 steps | 0.00 mean step batch | 0 seqs (0 joins)");
        dc.seqs.add(3);
        dc.joins.inc();
        dc.record_step(2);
        dc.record_step(3);
        dc.record_step(3);
        dc.kv_leases.add(3);
        dc.kv_refusals.inc();
        dc.solo_fallbacks.inc();
        let dm = dc.snapshot();
        assert_eq!(dm.steps, 3);
        assert_eq!(dm.tokens, 8);
        assert!((dm.mean_step_batch() - 8.0 / 3.0).abs() < 1e-9);
        let busy = decode_summary(&dm);
        assert!(busy.contains("3 steps"));
        assert!(busy.contains("3 seqs (1 joins)"));
        assert!(busy.contains("kv: 3 leases, 1 refusals, 1 solo fallbacks"));
        // Addressable through the registry under the decode.* names.
        let snap = reg.snapshot();
        assert_eq!(snap.counter("decode.steps"), Some(3));
        assert_eq!(snap.counter("decode.tokens"), Some(8));
        assert_eq!(snap.histogram("decode.step_batch").unwrap().count, 3);
    }

    #[test]
    fn cache_summary_mentions_paging_and_prefetch_only_when_active() {
        let mut cm = CacheMetrics::default();
        cm.hits = 3;
        cm.misses = 1;
        let plain = cache_summary(&cm);
        assert!(plain.contains("hit rate"));
        assert!(!plain.contains("shard"));
        assert!(!plain.contains("prefetch"));
        cm.shard_fetches = 5;
        cm.prefetch_misses = 2;
        cm.prefetch_useful = 1;
        let paged = cache_summary(&cm);
        assert!(paged.contains("shard fetches"));
        assert!(paged.contains("50 % useful"));
        assert!(!paged.contains("singleflight"), "quiet until concurrency dedups something");
        cm.singleflight_waits = 3;
        cm.dedup_fetches = 4;
        let contended = cache_summary(&cm);
        assert!(contended.contains("singleflight: 3 waits, 4 deduped, 0 publish races lost"));
        assert!(!contended.contains("faults"), "quiet until something fails");
        cm.transient_errors = 2;
        cm.fetch_retries = 2;
        cm.quarantined_shards = 1;
        cm.degraded_serves = 5;
        let faulted = cache_summary(&cm);
        assert!(faulted
            .contains("faults: 2 transient, 2 retries, 1 quarantines, 5 degraded, 0 prefetch errors"));
    }

    /// Golden-line pins: `cache_summary` and `batch_summary` are parsed by
    /// scripts/ci.sh and external dashboards. These assert the EXACT full
    /// strings; if a format change is intentional, update the goldens and
    /// the parsers together.
    #[test]
    fn summary_lines_match_golden_format() {
        let cm = CacheMetrics {
            hits: 75,
            misses: 25,
            restore_serves: 10,
            fused_serves: 15,
            evictions: 2,
            shard_fetches: 5,
            shard_fetch_ns: 2_500_000,
            shard_bytes: 3 * 1024 * 1024,
            shard_evictions: 1,
            prefetch_hits: 4,
            prefetch_misses: 8,
            prefetch_useful: 6,
            prefetch_dropped: 1,
            singleflight_waits: 3,
            dedup_fetches: 4,
            publish_races_lost: 1,
            ..CacheMetrics::default()
        };
        assert_eq!(
            cache_summary(&cm),
            "cache: 75.0 % hit rate | 10 restores / 15 fused serves | 2 evictions \
             | 5 shard fetches (2.50 ms, 3.0 MB decoded), 1 shard evictions \
             | prefetch: 4 hits / 8 loads, 75 % useful, 1 dropped \
             | singleflight: 3 waits, 4 deduped, 1 publish races lost"
        );

        let mut bm = BatchMetrics::default();
        bm.record_window(1);
        bm.solo_requests += 1;
        bm.record_window(4);
        bm.batched_requests += 4;
        bm.record_flush(FlushReason::Full, 120);
        bm.record_flush(FlushReason::Linger, 480);
        bm.record_dispatch(4);
        bm.record_dispatch(9);
        assert_eq!(
            batch_summary(&bm),
            "batch: 2 windows | 2.50 mean occupancy [1:1 2:0 3-4:1 5-8:0 >8:0] \
             | 4 batched / 1 solo requests \
             | flushes 1 full / 1 linger / 0 closed, 300 us mean linger \
             | 6.50 rows/expert dispatch [1:0 2:0 3-4:1 5-8:0 >8:1]"
        );
    }
}
