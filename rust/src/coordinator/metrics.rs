//! Serving metrics: latency percentiles, throughput, batch-size histogram,
//! and the cache/paging summary line.

use super::cache::CacheMetrics;
use crate::util::stats::percentile;
use std::time::Duration;

#[derive(Debug, Default, Clone)]
pub struct ServerMetrics {
    pub latencies_s: Vec<f64>,
    pub batch_sizes: Vec<usize>,
    pub tokens_processed: u64,
    pub wall_s: f64,
}

impl ServerMetrics {
    pub fn record_request(&mut self, latency: Duration) {
        self.latencies_s.push(latency.as_secs_f64());
    }

    pub fn record_batch(&mut self, size: usize, tokens: u64) {
        self.batch_sizes.push(size);
        self.tokens_processed += tokens;
    }

    pub fn p50_ms(&self) -> f64 {
        percentile(&self.latencies_s, 50.0) * 1e3
    }

    pub fn p99_ms(&self) -> f64 {
        percentile(&self.latencies_s, 99.0) * 1e3
    }

    pub fn mean_batch(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            0.0
        } else {
            self.batch_sizes.iter().sum::<usize>() as f64 / self.batch_sizes.len() as f64
        }
    }

    pub fn requests_per_s(&self) -> f64 {
        if self.wall_s <= 0.0 {
            0.0
        } else {
            self.latencies_s.len() as f64 / self.wall_s
        }
    }

    pub fn tokens_per_s(&self) -> f64 {
        if self.wall_s <= 0.0 {
            0.0
        } else {
            self.tokens_processed as f64 / self.wall_s
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "{} requests | {:.1} req/s | {:.0} tok/s | p50 {:.2} ms | p99 {:.2} ms | mean batch {:.1}",
            self.latencies_s.len(),
            self.requests_per_s(),
            self.tokens_per_s(),
            self.p50_ms(),
            self.p99_ms(),
            self.mean_batch()
        )
    }
}

/// One-line cache/paging story for demo + CLI output: hit rate, the
/// fused-vs-restore decision split, shard paging traffic, and prefetch
/// effectiveness.
pub fn cache_summary(cm: &CacheMetrics) -> String {
    let mut line = format!(
        "cache: {:.1} % hit rate | {} restores / {} fused serves | {} evictions",
        cm.hit_rate() * 100.0,
        cm.restore_serves,
        cm.fused_serves,
        cm.evictions
    );
    if cm.shard_fetches > 0 {
        line.push_str(&format!(
            " | {} shard fetches ({:.2} ms, {} decoded), {} shard evictions",
            cm.shard_fetches,
            cm.shard_fetch_ns as f64 / 1e6,
            crate::util::format_bytes(cm.shard_bytes as usize),
            cm.shard_evictions
        ));
    }
    if cm.prefetch_hits + cm.prefetch_misses > 0 {
        line.push_str(&format!(
            " | prefetch: {} hits / {} loads, {:.0} % useful, {} dropped",
            cm.prefetch_hits,
            cm.prefetch_misses,
            cm.prefetch_usefulness() * 100.0,
            cm.prefetch_dropped
        ));
    }
    if cm.singleflight_waits + cm.dedup_fetches + cm.publish_races_lost > 0 {
        line.push_str(&format!(
            " | singleflight: {} waits, {} deduped, {} publish races lost",
            cm.singleflight_waits, cm.dedup_fetches, cm.publish_races_lost
        ));
    }
    line
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_and_rates() {
        let mut m = ServerMetrics::default();
        for i in 1..=100 {
            m.record_request(Duration::from_millis(i));
        }
        m.record_batch(4, 400);
        m.record_batch(8, 800);
        m.wall_s = 2.0;
        assert!((m.p50_ms() - 50.5).abs() < 1.0);
        assert!(m.p99_ms() > 98.0);
        assert_eq!(m.mean_batch(), 6.0);
        assert_eq!(m.requests_per_s(), 50.0);
        assert_eq!(m.tokens_per_s(), 600.0);
        assert!(!m.summary().is_empty());
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = ServerMetrics::default();
        assert_eq!(m.p50_ms(), 0.0);
        assert_eq!(m.mean_batch(), 0.0);
        assert_eq!(m.requests_per_s(), 0.0);
    }

    #[test]
    fn cache_summary_mentions_paging_and_prefetch_only_when_active() {
        let mut cm = CacheMetrics::default();
        cm.hits = 3;
        cm.misses = 1;
        let plain = cache_summary(&cm);
        assert!(plain.contains("hit rate"));
        assert!(!plain.contains("shard"));
        assert!(!plain.contains("prefetch"));
        cm.shard_fetches = 5;
        cm.prefetch_misses = 2;
        cm.prefetch_useful = 1;
        let paged = cache_summary(&cm);
        assert!(paged.contains("shard fetches"));
        assert!(paged.contains("50 % useful"));
        assert!(!paged.contains("singleflight"), "quiet until concurrency dedups something");
        cm.singleflight_waits = 3;
        cm.dedup_fetches = 4;
        let contended = cache_summary(&cm);
        assert!(contended.contains("singleflight: 3 waits, 4 deduped, 0 publish races lost"));
    }
}
