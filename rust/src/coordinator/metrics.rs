//! Serving metrics: latency percentiles, throughput, batch-size histogram,
//! the continuous-batching window/occupancy story, and the cache/paging
//! summary line.

use super::batcher::FlushReason;
use super::cache::CacheMetrics;
use crate::util::stats::percentile;
use std::time::Duration;

#[derive(Debug, Default, Clone)]
pub struct ServerMetrics {
    pub latencies_s: Vec<f64>,
    pub batch_sizes: Vec<usize>,
    pub tokens_processed: u64,
    pub wall_s: f64,
}

impl ServerMetrics {
    pub fn record_request(&mut self, latency: Duration) {
        self.latencies_s.push(latency.as_secs_f64());
    }

    pub fn record_batch(&mut self, size: usize, tokens: u64) {
        self.batch_sizes.push(size);
        self.tokens_processed += tokens;
    }

    pub fn p50_ms(&self) -> f64 {
        percentile(&self.latencies_s, 50.0) * 1e3
    }

    pub fn p99_ms(&self) -> f64 {
        percentile(&self.latencies_s, 99.0) * 1e3
    }

    pub fn mean_batch(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            0.0
        } else {
            self.batch_sizes.iter().sum::<usize>() as f64 / self.batch_sizes.len() as f64
        }
    }

    pub fn requests_per_s(&self) -> f64 {
        if self.wall_s <= 0.0 {
            0.0
        } else {
            self.latencies_s.len() as f64 / self.wall_s
        }
    }

    pub fn tokens_per_s(&self) -> f64 {
        if self.wall_s <= 0.0 {
            0.0
        } else {
            self.tokens_processed as f64 / self.wall_s
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "{} requests | {:.1} req/s | {:.0} tok/s | p50 {:.2} ms | p99 {:.2} ms | mean batch {:.1}",
            self.latencies_s.len(),
            self.requests_per_s(),
            self.tokens_per_s(),
            self.p50_ms(),
            self.p99_ms(),
            self.mean_batch()
        )
    }
}

/// Histogram buckets shared by the occupancy and rows-per-expert
/// histograms: 1, 2, 3–4, 5–8, >8.
pub const BATCH_BUCKETS: [&str; 5] = ["1", "2", "3-4", "5-8", ">8"];

fn bucket_of(n: usize) -> usize {
    match n {
        0 | 1 => 0,
        2 => 1,
        3..=4 => 2,
        5..=8 => 3,
        _ => 4,
    }
}

/// Continuous-batching counters: how windows form (occupancy, flush
/// reasons, linger) and how much cross-request row sharing each expert
/// dispatch actually sees. Recorded by `Engine::handle_batch` and the
/// batched FFN hook; surfaced through [`batch_summary`] so the counters
/// can't silently rot (a unit test pins the line's contents).
#[derive(Debug, Default, Clone)]
pub struct BatchMetrics {
    /// Batch windows executed end-to-end (one `Engine::handle_batch` call).
    pub windows: u64,
    /// Requests that shared a multi-request batched prefill run.
    pub batched_requests: u64,
    /// Requests served alone: windows of one, sequential (generate)
    /// requests, and invalid requests answered without a forward.
    pub solo_requests: u64,
    /// Window flush reasons (from the admission queue; direct
    /// `handle_batch` calls don't record one).
    pub full_flushes: u64,
    pub linger_flushes: u64,
    pub closed_flushes: u64,
    /// Total µs flushed windows' oldest requests lingered. Mean = divided
    /// by the flush count (full + linger + closed), NOT by `windows` —
    /// direct `handle_batch` calls record a window but no flush.
    pub linger_us: u64,
    /// Window occupancy histogram over [`BATCH_BUCKETS`].
    pub occupancy: [u64; 5],
    /// Rows-per-expert-dispatch histogram over [`BATCH_BUCKETS`] — the
    /// direct measure of how much work concatenation fuses per expert.
    pub rows_per_expert: [u64; 5],
    /// Expert dispatch calls and their total rows (mean rows/dispatch).
    pub expert_dispatches: u64,
    pub expert_rows: u64,
}

impl BatchMetrics {
    /// Record one executed window of `size` requests.
    pub fn record_window(&mut self, size: usize) {
        self.windows += 1;
        self.occupancy[bucket_of(size)] += 1;
    }

    /// Record the admission-queue flush that produced a window.
    pub fn record_flush(&mut self, reason: FlushReason, waited_us: u64) {
        match reason {
            FlushReason::Full => self.full_flushes += 1,
            FlushReason::Linger => self.linger_flushes += 1,
            FlushReason::Closed => self.closed_flushes += 1,
        }
        self.linger_us += waited_us;
    }

    /// Record one expert dispatch over `rows` concatenated rows.
    pub fn record_dispatch(&mut self, rows: usize) {
        self.expert_dispatches += 1;
        self.expert_rows += rows as u64;
        self.rows_per_expert[bucket_of(rows)] += 1;
    }

    pub fn mean_occupancy(&self) -> f64 {
        if self.windows == 0 {
            0.0
        } else {
            (self.batched_requests + self.solo_requests) as f64 / self.windows as f64
        }
    }

    pub fn mean_rows_per_dispatch(&self) -> f64 {
        if self.expert_dispatches == 0 {
            0.0
        } else {
            self.expert_rows as f64 / self.expert_dispatches as f64
        }
    }

    pub fn mean_linger_us(&self) -> f64 {
        let flushes = self.full_flushes + self.linger_flushes + self.closed_flushes;
        if flushes == 0 {
            0.0
        } else {
            self.linger_us as f64 / flushes as f64
        }
    }
}

/// One-line continuous-batching story — the `cache_summary` analog for the
/// window scheduler: occupancy, flush split, linger, and per-expert row
/// fusion.
pub fn batch_summary(bm: &BatchMetrics) -> String {
    let hist = |h: &[u64; 5]| -> String {
        BATCH_BUCKETS
            .iter()
            .zip(h)
            .map(|(b, c)| format!("{b}:{c}"))
            .collect::<Vec<_>>()
            .join(" ")
    };
    let mut line = format!(
        "batch: {} windows | {:.2} mean occupancy [{}] | {} batched / {} solo requests",
        bm.windows,
        bm.mean_occupancy(),
        hist(&bm.occupancy),
        bm.batched_requests,
        bm.solo_requests,
    );
    if bm.full_flushes + bm.linger_flushes + bm.closed_flushes > 0 {
        line.push_str(&format!(
            " | flushes {} full / {} linger / {} closed, {:.0} us mean linger",
            bm.full_flushes,
            bm.linger_flushes,
            bm.closed_flushes,
            bm.mean_linger_us(),
        ));
    }
    if bm.expert_dispatches > 0 {
        line.push_str(&format!(
            " | {:.2} rows/expert dispatch [{}]",
            bm.mean_rows_per_dispatch(),
            hist(&bm.rows_per_expert),
        ));
    }
    line
}

/// One-line cache/paging story for demo + CLI output: hit rate, the
/// fused-vs-restore decision split, shard paging traffic, and prefetch
/// effectiveness.
pub fn cache_summary(cm: &CacheMetrics) -> String {
    let mut line = format!(
        "cache: {:.1} % hit rate | {} restores / {} fused serves | {} evictions",
        cm.hit_rate() * 100.0,
        cm.restore_serves,
        cm.fused_serves,
        cm.evictions
    );
    if cm.shard_fetches > 0 {
        line.push_str(&format!(
            " | {} shard fetches ({:.2} ms, {} decoded), {} shard evictions",
            cm.shard_fetches,
            cm.shard_fetch_ns as f64 / 1e6,
            crate::util::format_bytes(cm.shard_bytes as usize),
            cm.shard_evictions
        ));
    }
    if cm.prefetch_hits + cm.prefetch_misses > 0 {
        line.push_str(&format!(
            " | prefetch: {} hits / {} loads, {:.0} % useful, {} dropped",
            cm.prefetch_hits,
            cm.prefetch_misses,
            cm.prefetch_usefulness() * 100.0,
            cm.prefetch_dropped
        ));
    }
    if cm.singleflight_waits + cm.dedup_fetches + cm.publish_races_lost > 0 {
        line.push_str(&format!(
            " | singleflight: {} waits, {} deduped, {} publish races lost",
            cm.singleflight_waits, cm.dedup_fetches, cm.publish_races_lost
        ));
    }
    line
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_and_rates() {
        let mut m = ServerMetrics::default();
        for i in 1..=100 {
            m.record_request(Duration::from_millis(i));
        }
        m.record_batch(4, 400);
        m.record_batch(8, 800);
        m.wall_s = 2.0;
        assert!((m.p50_ms() - 50.5).abs() < 1.0);
        assert!(m.p99_ms() > 98.0);
        assert_eq!(m.mean_batch(), 6.0);
        assert_eq!(m.requests_per_s(), 50.0);
        assert_eq!(m.tokens_per_s(), 600.0);
        assert!(!m.summary().is_empty());
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = ServerMetrics::default();
        assert_eq!(m.p50_ms(), 0.0);
        assert_eq!(m.mean_batch(), 0.0);
        assert_eq!(m.requests_per_s(), 0.0);
    }

    #[test]
    fn batch_summary_surfaces_every_counter_family() {
        let mut bm = BatchMetrics::default();
        // Quiet engine: windows only.
        bm.record_window(1);
        bm.solo_requests += 1;
        let quiet = batch_summary(&bm);
        assert!(quiet.contains("1 windows"));
        assert!(quiet.contains("[1:1 2:0 3-4:0 5-8:0 >8:0]"));
        assert!(!quiet.contains("flushes"), "no queue flushes recorded yet");
        assert!(!quiet.contains("dispatch"), "no expert dispatches recorded yet");
        // A busy window: occupancy 4, full flush after 120 us, two expert
        // dispatches fusing 4 + 9 rows.
        bm.record_window(4);
        bm.batched_requests += 4;
        bm.record_flush(FlushReason::Full, 120);
        bm.record_flush(FlushReason::Linger, 480);
        bm.record_dispatch(4);
        bm.record_dispatch(9);
        assert_eq!(bm.occupancy, [1, 0, 1, 0, 0]);
        assert_eq!(bm.rows_per_expert, [0, 0, 1, 0, 1]);
        assert!((bm.mean_occupancy() - 2.5).abs() < 1e-9);
        assert!((bm.mean_rows_per_dispatch() - 6.5).abs() < 1e-9);
        assert!((bm.mean_linger_us() - 300.0).abs() < 1e-9);
        let busy = batch_summary(&bm);
        assert!(busy.contains("2 windows"));
        assert!(busy.contains("flushes 1 full / 1 linger / 0 closed"));
        assert!(busy.contains("300 us mean linger"));
        assert!(busy.contains("6.50 rows/expert dispatch"));
        assert!(busy.contains("3-4:1 5-8:0 >8:1"), "{busy}");
    }

    #[test]
    fn cache_summary_mentions_paging_and_prefetch_only_when_active() {
        let mut cm = CacheMetrics::default();
        cm.hits = 3;
        cm.misses = 1;
        let plain = cache_summary(&cm);
        assert!(plain.contains("hit rate"));
        assert!(!plain.contains("shard"));
        assert!(!plain.contains("prefetch"));
        cm.shard_fetches = 5;
        cm.prefetch_misses = 2;
        cm.prefetch_useful = 1;
        let paged = cache_summary(&cm);
        assert!(paged.contains("shard fetches"));
        assert!(paged.contains("50 % useful"));
        assert!(!paged.contains("singleflight"), "quiet until concurrency dedups something");
        cm.singleflight_waits = 3;
        cm.dedup_fetches = 4;
        let contended = cache_summary(&cm);
        assert!(contended.contains("singleflight: 3 waits, 4 deduped, 0 publish races lost"));
    }
}
