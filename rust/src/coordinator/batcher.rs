//! Continuous-batching admission queue.
//!
//! The scheduling core is [`Batcher`] — a **pure, virtual-clock state
//! machine** (no wall time, no I/O): requests are `push`ed with an explicit
//! arrival timestamp, and `poll` decides when a window flushes. Window
//! policy (the vLLM-style latency/throughput knob):
//!
//! - **Full flush**: `max_batch` requests are pending → flush immediately.
//! - **Linger flush**: the oldest pending request has waited `linger_us` →
//!   flush whatever is pending (a lone straggler ships as a window of 1).
//! - **Close flush**: the queue is shut down → drain everything pending.
//!
//! Requests are never dropped and never reordered: a window is always a
//! contiguous, arrival-ordered slice of the admission sequence — the
//! serving engine's batched == serial bit-identity proof assumes exactly
//! that.
//!
//! Determinism is the point of the split: the replay tests below drive the
//! state machine over scripted arrival traces with a virtual clock and
//! assert exact window compositions. Wall time enters only in
//! [`next_window`], the thin mpsc driver the server's workers run.
//!
//! Knobs: [`BatchPolicy::from_env`] reads `RESMOE_BATCH` (max window size)
//! and `RESMOE_LINGER_US` (max linger), so deployments tune the
//! latency/throughput trade without a rebuild.

use std::collections::VecDeque;
use std::sync::mpsc::{Receiver, RecvTimeoutError, TryRecvError};
use std::time::{Duration, Instant};

/// Window-forming policy: flush at `max_batch` requests, or once the
/// oldest pending request has lingered `linger_us` microseconds.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub linger_us: u64,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, linger_us: 500 }
    }
}

impl BatchPolicy {
    /// Defaults overridden by `RESMOE_BATCH` / `RESMOE_LINGER_US` (invalid
    /// or missing values keep the default; `RESMOE_BATCH=0` clamps to 1 —
    /// a zero-wide window could never flush).
    pub fn from_env() -> BatchPolicy {
        Self::from_lookup(|name| std::env::var(name).ok())
    }

    /// [`BatchPolicy::from_env`] with the variable source injected — tests
    /// exercise the parsing/clamping without mutating process-global env
    /// (setenv races getenv in a multithreaded test harness). Parsing goes
    /// through [`crate::util::env`]: garbage → default, overflow-wide
    /// digit strings saturate to `u64::MAX` instead of falling back.
    pub fn from_lookup(lookup: impl Fn(&str) -> Option<String>) -> BatchPolicy {
        let d = BatchPolicy::default();
        BatchPolicy {
            max_batch: crate::util::env::knob_usize(&lookup, "RESMOE_BATCH", d.max_batch)
                .max(1),
            linger_us: crate::util::env::knob_u64(&lookup, "RESMOE_LINGER_US", d.linger_us),
        }
    }
}

/// Why a window flushed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlushReason {
    /// `max_batch` requests were pending.
    Full,
    /// The oldest pending request hit the linger deadline.
    Linger,
    /// The queue was closed (shutdown drain).
    Closed,
}

/// One flushed batch window.
#[derive(Debug)]
pub struct Window<T> {
    /// The requests, in arrival order (never reordered, never dropped).
    pub items: Vec<T>,
    pub reason: FlushReason,
    /// How long the window's oldest request waited before the flush.
    pub waited_us: u64,
}

/// The deterministic admission-queue state machine. All methods take an
/// explicit `now_us` virtual timestamp; nothing here reads a real clock.
pub struct Batcher<T> {
    policy: BatchPolicy,
    /// Pending requests with their arrival stamps, in arrival order.
    pending: VecDeque<(T, u64)>,
    closed: bool,
}

impl<T> Batcher<T> {
    pub fn new(policy: BatchPolicy) -> Batcher<T> {
        Batcher { policy, pending: VecDeque::new(), closed: false }
    }

    /// Admit a request at virtual time `now_us`.
    pub fn push(&mut self, item: T, now_us: u64) {
        debug_assert!(!self.closed, "push after close");
        self.pending.push_back((item, now_us));
    }

    /// No requests pending (a closed, drained batcher is idle forever).
    pub fn is_idle(&self) -> bool {
        self.pending.is_empty()
    }

    /// Requests admitted but not yet flushed into a window — the queued
    /// component of a virtual-depth calculation (see the loadgen harness).
    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    pub fn is_closed(&self) -> bool {
        self.closed
    }

    /// The virtual time at which the current window must flush even if no
    /// further request arrives (`None` when nothing is pending — the
    /// driver blocks indefinitely for the first arrival). Saturating:
    /// `RESMOE_LINGER_US=u64::MAX` means "never linger-flush", and an
    /// unchecked `arrived + linger` would wrap to a deadline in the past
    /// and flush every window instantly instead.
    pub fn deadline_us(&self) -> Option<u64> {
        self.pending
            .front()
            .map(|&(_, arrived)| arrived.saturating_add(self.policy.linger_us))
    }

    /// Mark the queue closed: no further `push`es; the next `poll` drains
    /// whatever is pending.
    pub fn close(&mut self) {
        self.closed = true;
    }

    /// Flush decision at virtual time `now_us`. Returns the next window,
    /// or `None` if no flush condition holds yet. Full windows take
    /// `max_batch` items and leave the remainder pending (their linger
    /// clocks — per-item arrival stamps — keep running); linger and close
    /// flushes drain up to `max_batch` of the oldest pending items.
    pub fn poll(&mut self, now_us: u64) -> Option<Window<T>> {
        if self.pending.is_empty() {
            return None;
        }
        let reason = if self.pending.len() >= self.policy.max_batch {
            FlushReason::Full
        } else if self.closed {
            FlushReason::Closed
        } else if now_us >= self.deadline_us().expect("nonempty") {
            FlushReason::Linger
        } else {
            return None;
        };
        let take = self.pending.len().min(self.policy.max_batch);
        let oldest = self.pending.front().expect("nonempty").1;
        let items = self.pending.drain(..take).map(|(item, _)| item).collect();
        Some(Window { items, reason, waited_us: now_us.saturating_sub(oldest) })
    }
}

/// Wall-clock driver for the server's worker loop: block on `rx` for the
/// first arrival, admit stragglers until the state machine flushes, and
/// return the window. Returns `None` only when the channel is closed AND
/// the batcher has fully drained — no request is ever dropped on shutdown.
/// `epoch` anchors the virtual clock (shared across calls so per-item
/// arrival stamps stay comparable).
pub fn next_window<T>(
    rx: &Receiver<T>,
    batcher: &mut Batcher<T>,
    epoch: Instant,
) -> Option<Window<T>> {
    loop {
        let now_us = epoch.elapsed().as_micros() as u64;
        if let Some(w) = batcher.poll(now_us) {
            return Some(w);
        }
        if batcher.is_closed() {
            // Closed and poll returned None → fully drained.
            return None;
        }
        match batcher.deadline_us() {
            // Nothing pending: block for the first arrival of the next
            // window.
            None => match rx.recv() {
                Ok(item) => {
                    let now = epoch.elapsed().as_micros() as u64;
                    batcher.push(item, now);
                }
                Err(_) => batcher.close(),
            },
            // Window open: accept stragglers until the linger deadline.
            // (`deadline - now` cannot underflow: the `now >= deadline`
            // branch above runs first, and the deadline itself saturates.)
            Some(deadline) => {
                let now = epoch.elapsed().as_micros() as u64;
                if now >= deadline {
                    continue; // next poll linger-flushes
                }
                match rx.recv_timeout(Duration::from_micros(deadline - now)) {
                    Ok(item) => {
                        let at = epoch.elapsed().as_micros() as u64;
                        batcher.push(item, at);
                    }
                    Err(RecvTimeoutError::Timeout) => {} // next poll flushes
                    Err(RecvTimeoutError::Disconnected) => batcher.close(),
                }
            }
        }
    }
}

/// Non-blocking sibling of [`next_window`] for a worker whose decode lane
/// is active: drain whatever is already sitting in `rx`, then ask the
/// state machine for a window at the current virtual time — never sleeps,
/// so the decode batch keeps stepping between polls. Returns `None` both
/// when no flush condition holds yet and when the batcher has drained
/// after close; `batcher.is_closed() && batcher.is_idle()` distinguishes
/// shutdown.
pub fn poll_window<T>(
    rx: &Receiver<T>,
    batcher: &mut Batcher<T>,
    epoch: Instant,
) -> Option<Window<T>> {
    loop {
        match rx.try_recv() {
            Ok(item) => {
                let now = epoch.elapsed().as_micros() as u64;
                batcher.push(item, now);
            }
            Err(TryRecvError::Empty) => break,
            Err(TryRecvError::Disconnected) => {
                batcher.close();
                break;
            }
        }
    }
    batcher.poll(epoch.elapsed().as_micros() as u64)
}

// --------------------------------------------------------------- decode

/// Decode-lane policy: how many sequences one iteration-level decode
/// batch may hold. `RESMOE_DECODE_BATCH` overrides (0 clamps to 1 — a
/// zero-wide decode batch could never finish a request).
#[derive(Debug, Clone, Copy)]
pub struct DecodePolicy {
    pub max_batch: usize,
}

impl Default for DecodePolicy {
    fn default() -> Self {
        DecodePolicy { max_batch: 8 }
    }
}

impl DecodePolicy {
    pub fn from_env() -> DecodePolicy {
        Self::from_lookup(|name| std::env::var(name).ok())
    }

    pub fn from_lookup(lookup: impl Fn(&str) -> Option<String>) -> DecodePolicy {
        let d = DecodePolicy::default();
        DecodePolicy {
            max_batch: crate::util::env::knob_usize(&lookup, "RESMOE_DECODE_BATCH", d.max_batch)
                .max(1),
        }
    }
}

/// One sequence inside the decode scheduler.
#[derive(Debug)]
struct DecodeSeq {
    ticket: u64,
    prompt: Vec<u32>,
    max_new: usize,
    max_seq: usize,
    /// Tokens fed through the model so far (prompt prefix + produced
    /// continuation, minus the final produced token, which is never fed —
    /// its logits would be discarded).
    fed: usize,
    produced: Vec<u32>,
}

/// A retired sequence handed back by [`DecodeScheduler::record`].
#[derive(Debug)]
pub struct DecodeFinished {
    pub ticket: u64,
    pub produced: Vec<u32>,
    /// Tokens this sequence fed through the model (the conservation-law
    /// operand: `fed == prompt_len + max(produced_len, 1) - 1` for every
    /// sequence that retired by producing at least one token).
    pub fed: usize,
    pub prompt_len: usize,
}

/// The iteration-level decode scheduler: a **pure token-bookkeeping state
/// machine** (no model, no I/O, no clock) deciding which token each
/// active sequence feeds next and when a sequence retires. The server
/// drives it: `plan` → run one batched model step over the planned tokens
/// → `record` the resulting logits (greedy argmax happens here so batched
/// and solo serving share one sampling rule) → reply to whatever
/// `record` retired. Admission may happen between any two steps — that is
/// the continuous-batching property; a joining sequence simply starts
/// feeding its prompt while its neighbors are mid-generation.
///
/// Token semantics match the serial reference exactly: a sequence
/// produces `min(max_new, max_seq - prompt_len)` tokens (greedy argmax
/// with the same tie-break fold as [`crate::moe::Model::generate`]),
/// except the final produced token is never fed
/// back — the serial loop feeds it and discards the logits, a wasted step
/// the batched lane skips.
///
/// Conservation laws (pinned by the relaxed-parity harness):
/// `admitted == finished + active`, `tokens_fed == Σ fed` over all
/// sequences, and every retired sequence satisfies the `fed` identity on
/// [`DecodeFinished`].
#[derive(Debug)]
pub struct DecodeScheduler {
    policy: DecodePolicy,
    /// Active sequences in admission order — also the batch row order of
    /// every `plan`/`record` pair, so step composition is deterministic.
    seqs: Vec<DecodeSeq>,
    next_ticket: u64,
    admitted: u64,
    finished: u64,
    steps: u64,
    tokens_fed: u64,
}

impl DecodeScheduler {
    pub fn new(policy: DecodePolicy) -> DecodeScheduler {
        DecodeScheduler {
            policy,
            seqs: Vec::new(),
            next_ticket: 0,
            admitted: 0,
            finished: 0,
            steps: 0,
            tokens_fed: 0,
        }
    }

    pub fn has_room(&self) -> bool {
        self.seqs.len() < self.policy.max_batch
    }

    pub fn active(&self) -> usize {
        self.seqs.len()
    }

    pub fn is_idle(&self) -> bool {
        self.seqs.is_empty()
    }

    pub fn admitted(&self) -> u64 {
        self.admitted
    }

    pub fn finished(&self) -> u64 {
        self.finished
    }

    pub fn steps(&self) -> u64 {
        self.steps
    }

    pub fn tokens_fed(&self) -> u64 {
        self.tokens_fed
    }

    /// Admit a sequence; returns its ticket. The caller is responsible
    /// for capacity (`has_room`) and for prompt validity (non-empty,
    /// shorter than `max_seq`) — the server's shape check runs first.
    pub fn admit(&mut self, prompt: Vec<u32>, max_new: usize, max_seq: usize) -> u64 {
        debug_assert!(self.has_room(), "admit past decode batch cap");
        debug_assert!(!prompt.is_empty() && prompt.len() < max_seq);
        let ticket = self.next_ticket;
        self.next_ticket += 1;
        self.admitted += 1;
        self.seqs.push(DecodeSeq {
            ticket,
            prompt,
            max_new,
            max_seq,
            fed: 0,
            produced: Vec::new(),
        });
        ticket
    }

    /// The next step's feed: `(ticket, token)` for every active sequence
    /// in admission order. Empty when idle.
    pub fn plan(&self) -> Vec<(u64, u32)> {
        self.seqs
            .iter()
            .map(|s| {
                let tok = if s.fed < s.prompt.len() {
                    s.prompt[s.fed]
                } else {
                    // Invariant: past the prompt, the previous `record`
                    // sampled a token that has not been fed yet.
                    *s.produced.last().expect("sampled token pending feed")
                };
                (s.ticket, tok)
            })
            .collect()
    }

    /// Complete one step: `logits[i]` is the model output for the i-th
    /// entry of the step's `plan`. Samples greedily where a sequence has
    /// finished its prompt, retires sequences that hit `max_new`, a
    /// `max_seq`-bounded budget, or produced their final token. Returns
    /// the retired sequences, in admission order.
    pub fn record(&mut self, logits: &[Vec<f32>]) -> Vec<DecodeFinished> {
        assert_eq!(logits.len(), self.seqs.len(), "one logit row per active sequence");
        self.steps += 1;
        self.tokens_fed += logits.len() as u64;
        let mut done = Vec::new();
        let mut keep = Vec::with_capacity(self.seqs.len());
        for (seq, lg) in std::mem::take(&mut self.seqs).into_iter().zip(logits) {
            let mut s = seq;
            s.fed += 1;
            let mut retire = false;
            if s.fed >= s.prompt.len() {
                // Same produce condition as the serial loop: token k
                // exists iff k < max_new and prompt_len + k < max_seq.
                let k = s.produced.len();
                if k < s.max_new && s.prompt.len() + k < s.max_seq {
                    let next = lg
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .map(|(i, _)| i as u32)
                        .unwrap();
                    s.produced.push(next);
                    // The final token is sampled but never fed back.
                    let k = s.produced.len();
                    retire = k >= s.max_new || s.prompt.len() + k >= s.max_seq;
                } else {
                    retire = true;
                }
            }
            if retire {
                self.finished += 1;
                done.push(DecodeFinished {
                    ticket: s.ticket,
                    produced: s.produced,
                    fed: s.fed,
                    prompt_len: s.prompt.len(),
                });
            } else {
                keep.push(s);
            }
        }
        self.seqs = keep;
        done
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn policy(max_batch: usize, linger_us: u64) -> BatchPolicy {
        BatchPolicy { max_batch, linger_us }
    }

    // ---------------------------------------- deterministic replay traces
    //
    // The four scripted trace shapes of the scheduler-replay satellite:
    // full-batch flush, linger-expiry flush, single straggler, and
    // quiesce-on-shutdown — all driven by a virtual clock, asserting exact
    // window compositions.

    #[test]
    fn replay_full_batch_flush() {
        let mut b = Batcher::new(policy(4, 1000));
        for (i, t) in [(0u32, 10u64), (1, 20), (2, 30)] {
            b.push(i, t);
            assert!(b.poll(t).is_none(), "below max and before linger");
        }
        b.push(3, 40);
        let w = b.poll(40).expect("4th request fills the window");
        assert_eq!(w.items, vec![0, 1, 2, 3]);
        assert_eq!(w.reason, FlushReason::Full);
        assert_eq!(w.waited_us, 30, "oldest waited 40 - 10");
        assert!(b.is_idle());
        // A second burst overflowing max_batch: flush takes exactly
        // max_batch, remainder stays pending with its own linger clock.
        for i in 0..6u32 {
            b.push(10 + i, 100 + i as u64);
        }
        let w = b.poll(106).expect("over-full window");
        assert_eq!(w.items, vec![10, 11, 12, 13]);
        assert_eq!(w.reason, FlushReason::Full);
        assert_eq!(b.deadline_us(), Some(104 + 1000), "remainder keeps its arrival stamp");
        let w = b.poll(1104).expect("leftovers linger-flush at their own deadline");
        assert_eq!(w.items, vec![14, 15]);
        assert_eq!(w.reason, FlushReason::Linger);
    }

    #[test]
    fn replay_linger_expiry_flush() {
        let mut b = Batcher::new(policy(8, 500));
        b.push(1u32, 0);
        b.push(2, 200);
        b.push(3, 499);
        assert!(b.poll(499).is_none(), "deadline is first arrival + linger");
        let w = b.poll(500).expect("linger expiry");
        assert_eq!(w.items, vec![1, 2, 3]);
        assert_eq!(w.reason, FlushReason::Linger);
        assert_eq!(w.waited_us, 500);
        assert!(b.poll(10_000).is_none(), "nothing pending, nothing flushes");
    }

    #[test]
    fn replay_single_straggler() {
        // A lone request never joined by anyone must still ship — as a
        // window of one, exactly at its linger deadline.
        let mut b = Batcher::new(policy(8, 300));
        b.push(42u32, 1000);
        assert_eq!(b.deadline_us(), Some(1300));
        assert!(b.poll(1299).is_none());
        let w = b.poll(1300).expect("straggler flushes alone");
        assert_eq!(w.items, vec![42]);
        assert_eq!(w.reason, FlushReason::Linger);
        assert_eq!(w.waited_us, 300);
    }

    #[test]
    fn replay_quiesce_on_shutdown() {
        // Close with work pending: everything drains (no drops), in order,
        // before the batcher reports idle-and-closed.
        let mut b = Batcher::new(policy(4, 1_000_000));
        for i in 0..6u32 {
            b.push(i, i as u64);
        }
        b.close();
        let w = b.poll(10).expect("full window drains first");
        assert_eq!(w.items, vec![0, 1, 2, 3]);
        assert_eq!(w.reason, FlushReason::Full, "full beats closed while over max");
        let w = b.poll(10).expect("remainder drains on close, ignoring linger");
        assert_eq!(w.items, vec![4, 5]);
        assert_eq!(w.reason, FlushReason::Closed);
        assert!(b.poll(10).is_none());
        assert!(b.is_idle() && b.is_closed());
    }

    #[test]
    fn windows_preserve_admission_order_and_drop_nothing() {
        // Randomized trace: any interleaving of pushes and polls yields
        // windows that concatenate back to the exact admission sequence.
        let mut b = Batcher::new(policy(3, 50));
        let mut seen: Vec<u32> = Vec::new();
        let mut next = 0u32;
        let mut now = 0u64;
        for step in 0..200u64 {
            now += 1 + (step * 7) % 13;
            if step % 3 != 2 {
                b.push(next, now);
                next += 1;
            }
            if let Some(w) = b.poll(now) {
                seen.extend(&w.items);
            }
        }
        b.close();
        while let Some(w) = b.poll(now) {
            seen.extend(&w.items);
        }
        let want: Vec<u32> = (0..next).collect();
        assert_eq!(seen, want, "concatenated windows == admission order, nothing dropped");
    }

    #[test]
    fn policy_from_lookup_parses_and_clamps() {
        // Injected lookup — no process-global env mutation (setenv races
        // getenv under the parallel test harness).
        let env = |pairs: &'static [(&'static str, &'static str)]| {
            move |name: &str| {
                pairs.iter().find(|(k, _)| *k == name).map(|(_, v)| v.to_string())
            }
        };
        let p = BatchPolicy::from_lookup(env(&[("RESMOE_BATCH", "16"), ("RESMOE_LINGER_US", "250")]));
        assert_eq!(p.max_batch, 16);
        assert_eq!(p.linger_us, 250);
        let p = BatchPolicy::from_lookup(env(&[("RESMOE_BATCH", "0")]));
        assert_eq!(p.max_batch, 1, "zero-wide windows clamp to 1");
        let p = BatchPolicy::from_lookup(env(&[("RESMOE_BATCH", "bogus")]));
        assert_eq!(p.max_batch, BatchPolicy::default().max_batch);
        assert_eq!(p.linger_us, BatchPolicy::default().linger_us);
        let p = BatchPolicy::from_lookup(|_| None);
        assert_eq!(p.max_batch, BatchPolicy::default().max_batch);
    }

    #[test]
    fn policy_from_lookup_saturates_overflow_digits() {
        // Pre-fix, a digit string wider than u64 failed `parse()` and fell
        // back to the default — an operator's "effectively unbounded" knob
        // silently became 8/500. Now it saturates.
        let env = |pairs: &'static [(&'static str, &'static str)]| {
            move |name: &str| {
                pairs.iter().find(|(k, _)| *k == name).map(|(_, v)| v.to_string())
            }
        };
        let p = BatchPolicy::from_lookup(env(&[
            ("RESMOE_BATCH", "99999999999999999999999999"),
            ("RESMOE_LINGER_US", "99999999999999999999999999"),
        ]));
        assert_eq!(p.max_batch, usize::MAX);
        assert_eq!(p.linger_us, u64::MAX);
        // Exactly u64::MAX parses as itself in both the u64 and the
        // saturating-usize knob.
        let p = BatchPolicy::from_lookup(env(&[("RESMOE_LINGER_US", "18446744073709551615")]));
        assert_eq!(p.linger_us, u64::MAX);
    }

    #[test]
    fn extreme_linger_never_wraps_into_instant_flush() {
        // RESMOE_LINGER_US=u64::MAX means "never linger-flush". Pre-fix,
        // `arrived + linger` wrapped to `arrived - 1`, a deadline in the
        // past, so every window linger-flushed instantly.
        let mut b = Batcher::new(policy(8, u64::MAX));
        b.push(1u32, 100);
        assert_eq!(b.deadline_us(), Some(u64::MAX), "deadline saturates");
        assert!(b.poll(100).is_none(), "no instant linger flush");
        assert!(b.poll(u64::MAX - 1).is_none(), "never flushes at any finite time");
        // Full and close flushes still work under the extreme linger.
        for i in 2..=8u32 {
            b.push(i, 100 + i as u64);
        }
        let w = b.poll(200).expect("full flush unaffected");
        assert_eq!(w.reason, FlushReason::Full);
        b.push(99, 300);
        b.close();
        let w = b.poll(300).expect("close drains");
        assert_eq!(w.reason, FlushReason::Closed);
        // Late arrival stamps near u64::MAX can't overflow either.
        let mut b = Batcher::new(policy(8, 500));
        b.push(1u32, u64::MAX - 10);
        assert_eq!(b.deadline_us(), Some(u64::MAX));
    }

    // ---------------------------------------------------- decode scheduler

    /// Drive a scheduler against a fake "model" whose logits always argmax
    /// to `fed_token + 1 (mod 32)` — enough to check token bookkeeping
    /// without a transformer.
    fn fake_logits(tok: u32) -> Vec<f32> {
        let mut v = vec![0.0f32; 32];
        v[((tok + 1) % 32) as usize] = 1.0;
        v
    }

    #[test]
    fn decode_scheduler_matches_serial_token_semantics() {
        // produced == min(max_new, max_seq - prompt_len), greedy chain
        // tok+1, and the fed identity holds on retire.
        let mut s = DecodeScheduler::new(DecodePolicy { max_batch: 4 });
        let t = s.admit(vec![5, 6], 3, 24);
        let mut finished = Vec::new();
        while !s.is_idle() {
            let plan = s.plan();
            let logits: Vec<Vec<f32>> = plan.iter().map(|&(_, tok)| fake_logits(tok)).collect();
            finished.extend(s.record(&logits));
        }
        assert_eq!(finished.len(), 1);
        let f = &finished[0];
        assert_eq!(f.ticket, t);
        assert_eq!(f.produced, vec![7, 8, 9], "greedy chain from last prompt token");
        assert_eq!(f.fed, 2 + 3 - 1, "final produced token is never fed");
        assert_eq!(s.admitted(), 1);
        assert_eq!(s.finished(), 1);
    }

    #[test]
    fn decode_scheduler_caps_at_max_seq() {
        let mut s = DecodeScheduler::new(DecodePolicy { max_batch: 1 });
        let prompt: Vec<u32> = (0..20).collect();
        s.admit(prompt, 100, 24);
        let mut finished = Vec::new();
        while !s.is_idle() {
            let logits: Vec<Vec<f32>> =
                s.plan().iter().map(|&(_, tok)| fake_logits(tok)).collect();
            finished.extend(s.record(&logits));
        }
        assert_eq!(finished[0].produced.len(), 4, "max_seq - prompt_len");
    }

    #[test]
    fn decode_scheduler_interleaves_mid_decode_admissions() {
        // The continuous-batching property: a sequence admitted while
        // another is mid-generation joins the running batch, and both
        // finish with exactly the tokens they would produce alone.
        let mut s = DecodeScheduler::new(DecodePolicy { max_batch: 4 });
        let a = s.admit(vec![1, 2, 3], 4, 32);
        // Two steps of A alone (still feeding its prompt).
        for _ in 0..2 {
            let logits: Vec<Vec<f32>> =
                s.plan().iter().map(|&(_, tok)| fake_logits(tok)).collect();
            assert!(s.record(&logits).is_empty());
        }
        // B joins mid-flight.
        let b = s.admit(vec![9], 2, 32);
        assert_eq!(s.active(), 2);
        let plan = s.plan();
        assert_eq!(plan.len(), 2, "joined batch plans both sequences");
        assert_eq!(plan[0], (a, 3), "A feeds its last prompt token");
        assert_eq!(plan[1], (b, 9), "B starts its prompt in the same step");
        let mut done = Vec::new();
        let mut guard = 0;
        while !s.is_idle() {
            let logits: Vec<Vec<f32>> =
                s.plan().iter().map(|&(_, tok)| fake_logits(tok)).collect();
            done.extend(s.record(&logits));
            guard += 1;
            assert!(guard < 32, "must terminate");
        }
        let fa = done.iter().find(|f| f.ticket == a).unwrap();
        let fb = done.iter().find(|f| f.ticket == b).unwrap();
        assert_eq!(fa.produced, vec![4, 5, 6, 7], "A unaffected by B joining");
        assert_eq!(fb.produced, vec![10, 11]);
        // Conservation: admitted == finished + active, tokens_fed == Σ fed.
        assert_eq!(s.admitted(), 2);
        assert_eq!(s.finished(), 2);
        assert_eq!(s.active(), 0);
        assert_eq!(s.tokens_fed(), (fa.fed + fb.fed) as u64);
    }

    #[test]
    fn decode_scheduler_zero_max_new_retires_after_prompt() {
        let mut s = DecodeScheduler::new(DecodePolicy { max_batch: 1 });
        s.admit(vec![3, 4], 0, 32);
        let mut done = Vec::new();
        while !s.is_idle() {
            let logits: Vec<Vec<f32>> =
                s.plan().iter().map(|&(_, tok)| fake_logits(tok)).collect();
            done.extend(s.record(&logits));
        }
        assert_eq!(done[0].produced, Vec::<u32>::new());
        assert_eq!(done[0].fed, 2, "prompt still fully fed");
    }

    #[test]
    fn decode_policy_from_lookup_clamps() {
        let p = DecodePolicy::from_lookup(|n| {
            (n == "RESMOE_DECODE_BATCH").then(|| "16".to_string())
        });
        assert_eq!(p.max_batch, 16);
        let p = DecodePolicy::from_lookup(|n| {
            (n == "RESMOE_DECODE_BATCH").then(|| "0".to_string())
        });
        assert_eq!(p.max_batch, 1, "zero-wide decode batch clamps to 1");
        let p = DecodePolicy::from_lookup(|_| None);
        assert_eq!(p.max_batch, DecodePolicy::default().max_batch);
    }

    // ------------------------------------------------- wall-clock driver

    #[test]
    fn driver_returns_none_on_closed_empty_channel() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        let mut b = Batcher::new(policy(4, 1000));
        assert!(next_window(&rx, &mut b, Instant::now()).is_none());
    }

    #[test]
    fn driver_batches_up_to_max() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let epoch = Instant::now();
        let mut b = Batcher::new(policy(4, 5000));
        let w = next_window(&rx, &mut b, epoch).unwrap();
        assert_eq!(w.items, vec![0, 1, 2, 3]);
        assert_eq!(w.reason, FlushReason::Full);
        let w = next_window(&rx, &mut b, epoch).unwrap();
        assert_eq!(w.items, vec![4, 5, 6, 7]);
    }

    #[test]
    fn driver_flushes_partial_batch_after_linger() {
        let (tx, rx) = channel();
        tx.send(1u32).unwrap();
        let epoch = Instant::now();
        let mut b = Batcher::new(policy(8, 20_000));
        let t0 = Instant::now();
        let w = next_window(&rx, &mut b, epoch).unwrap();
        assert_eq!(w.items, vec![1]);
        assert_eq!(w.reason, FlushReason::Linger);
        assert!(t0.elapsed() >= Duration::from_millis(15));
        drop(tx);
    }

    #[test]
    fn driver_stragglers_join_within_window() {
        let (tx, rx) = channel();
        tx.send(1u32).unwrap();
        let sender = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(5));
            tx.send(2).unwrap();
            tx.send(3).unwrap();
        });
        let mut b = Batcher::new(policy(8, 100_000));
        let w = next_window(&rx, &mut b, Instant::now()).unwrap();
        sender.join().unwrap();
        assert!(w.items.len() >= 3, "items={:?}", w.items);
    }

    #[test]
    fn poll_window_never_blocks_and_drains_ready_items() {
        let (tx, rx) = channel();
        let epoch = Instant::now();
        let mut b = Batcher::new(policy(2, 1_000_000));
        // Empty channel: returns immediately with nothing.
        assert!(poll_window(&rx, &mut b, epoch).is_none());
        assert!(!b.is_closed());
        // Two queued items fill a window without waiting on linger.
        tx.send(1u32).unwrap();
        tx.send(2).unwrap();
        let w = poll_window(&rx, &mut b, epoch).expect("full window");
        assert_eq!(w.items, vec![1, 2]);
        assert_eq!(w.reason, FlushReason::Full);
        // One pending item below max: stays pending (no blocking, no
        // premature flush), then drains on disconnect.
        tx.send(3).unwrap();
        assert!(poll_window(&rx, &mut b, epoch).is_none());
        assert_eq!(b.pending_len(), 1);
        drop(tx);
        let w = poll_window(&rx, &mut b, epoch).expect("close drains");
        assert_eq!(w.items, vec![3]);
        assert_eq!(w.reason, FlushReason::Closed);
        assert!(b.is_closed() && b.is_idle());
    }

    #[test]
    fn driver_closed_mid_batch_returns_partial() {
        let (tx, rx) = channel();
        tx.send(7u32).unwrap();
        drop(tx);
        let mut b = Batcher::new(policy(8, 50_000));
        let w = next_window(&rx, &mut b, Instant::now()).unwrap();
        assert_eq!(w.items, vec![7]);
        assert_eq!(w.reason, FlushReason::Closed);
    }
}
