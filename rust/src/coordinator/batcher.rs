//! Dynamic request batcher: greedily drains the queue up to `batch_max`,
//! waiting at most `batch_wait` for stragglers once the first request of a
//! batch arrives (the vLLM-style latency/throughput knob).

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Collect the next batch from `rx`. Blocks until at least one item
/// arrives (or the channel closes → `None`), then keeps accepting items
/// until `batch_max` is reached or `batch_wait` elapses.
pub fn next_batch<T>(rx: &Receiver<T>, batch_max: usize, batch_wait: Duration) -> Option<Vec<T>> {
    let first = rx.recv().ok()?;
    let mut batch = vec![first];
    let deadline = Instant::now() + batch_wait;
    while batch.len() < batch_max {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => batch.push(item),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn returns_none_on_closed_channel() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        assert!(next_batch(&rx, 4, Duration::from_millis(1)).is_none());
    }

    #[test]
    fn batches_up_to_max() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let batch = next_batch(&rx, 4, Duration::from_millis(5)).unwrap();
        assert_eq!(batch, vec![0, 1, 2, 3]);
        let batch = next_batch(&rx, 4, Duration::from_millis(5)).unwrap();
        assert_eq!(batch, vec![4, 5, 6, 7]);
    }

    #[test]
    fn flushes_partial_batch_after_wait() {
        let (tx, rx) = channel();
        tx.send(1u32).unwrap();
        let t0 = Instant::now();
        let batch = next_batch(&rx, 8, Duration::from_millis(20)).unwrap();
        assert_eq!(batch, vec![1]);
        assert!(t0.elapsed() >= Duration::from_millis(15));
    }

    #[test]
    fn stragglers_join_within_window() {
        let (tx, rx) = channel();
        tx.send(1u32).unwrap();
        let sender = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(5));
            tx.send(2).unwrap();
            tx.send(3).unwrap();
        });
        let batch = next_batch(&rx, 8, Duration::from_millis(100)).unwrap();
        sender.join().unwrap();
        assert!(batch.len() >= 3, "batch={batch:?}");
    }

    #[test]
    fn closed_mid_batch_returns_partial() {
        let (tx, rx) = channel();
        tx.send(7u32).unwrap();
        drop(tx);
        let batch = next_batch(&rx, 8, Duration::from_millis(50)).unwrap();
        assert_eq!(batch, vec![7]);
    }
}
