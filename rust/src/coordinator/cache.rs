//! Restored-expert LRU cache — the paper's Algorithm 2 ("reconstruct and
//! dynamically load the compressed experts") as a serving-runtime feature —
//! plus the **fused-vs-restore cost model** for cache misses.
//!
//! Resident set: the per-layer barycenter `W_ω` lives inside the
//! [`CompressedLayer`] (always in memory, small); restored dense experts
//! are materialized on router demand into an LRU cache bounded by a byte
//! budget. When the budget is smaller than the full restored model, the
//! cache trades restore latency for memory — exactly the knob the paper's
//! space-efficiency argument is about.
//!
//! A miss no longer has to restore: [`ExpertCache::serve`] can answer with
//! the layer's [`FusedLayer`] instead, scoring tokens straight from the
//! compressed representation. The policy (see `should_restore`): restoring
//! pays a dense materialization once and makes every future hit free, so it
//! wins for experts that will stay resident; the fused path wins when the
//! budget cannot hold the expert anyway (thrash) or the expert is cold.
//! Decisions are recorded in [`CacheMetrics`].

use crate::compress::{CompressedLayer, FusedLayer};
use crate::moe::ExpertWeights;
use std::collections::HashMap;
use std::sync::Arc;

/// (block index, router slot) → restored expert.
type Key = (usize, usize);

#[derive(Debug, Default, Clone)]
pub struct CacheMetrics {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub restore_ns: u64,
    /// Misses answered by restoring + caching a dense expert.
    pub restore_serves: u64,
    /// Misses answered restore-free through the fused path.
    pub fused_serves: u64,
}

impl CacheMetrics {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// How [`ExpertCache::serve`] answers a lookup.
pub enum Serve {
    /// Dense weights: a cache hit, or a miss the policy chose to restore
    /// (and cache).
    Dense(Arc<ExpertWeights>),
    /// Restore-free: forward through [`FusedLayer::forward_slot`].
    Fused(Arc<FusedLayer>),
}

struct Entry {
    expert: Arc<ExpertWeights>,
    bytes: usize,
    /// LRU stamp (monotone counter).
    last_used: u64,
}

/// LRU cache of restored experts over a set of compressed layers.
pub struct ExpertCache {
    layers: HashMap<usize, CompressedLayer>,
    entries: HashMap<Key, Entry>,
    /// Lazily built fused state per block (`None` = layer has no center).
    fused: HashMap<usize, Option<Arc<FusedLayer>>>,
    /// Decayed per-key access counts driving the restore-vs-fused choice.
    heat: HashMap<Key, u32>,
    /// serve() calls so far — the decay clock for `heat`. Deliberately NOT
    /// the LRU `clock` (which get()/prefetch() also advance): decay must
    /// tick every HEAT_DECAY_PERIOD serves regardless of interleaving.
    serve_accesses: u64,
    /// Master switch for the fused path (benches compare both policies).
    fused_enabled: bool,
    budget_bytes: usize,
    used_bytes: usize,
    clock: u64,
    pub metrics: CacheMetrics,
}

fn expert_bytes(e: &ExpertWeights) -> usize {
    e.n_params() * 4
}

/// Accesses in the decay window after which a key counts as hot enough to
/// evict colder residents for (see `should_restore`).
const HOT_ACCESSES: u32 = 3;
/// Halve every heat counter each time this many accesses elapse, so "hot"
/// tracks the recent request mix rather than all of history.
const HEAT_DECAY_PERIOD: u64 = 256;
/// Sub-batches at least this large amortize a restore within the single
/// call, so restore regardless of heat.
const RESTORE_AMORTIZE_TOKENS: usize = 512;

impl ExpertCache {
    pub fn new(layers: Vec<(usize, CompressedLayer)>, budget_bytes: usize) -> ExpertCache {
        ExpertCache {
            layers: layers.into_iter().collect(),
            entries: HashMap::new(),
            fused: HashMap::new(),
            heat: HashMap::new(),
            serve_accesses: 0,
            fused_enabled: true,
            budget_bytes,
            used_bytes: 0,
            clock: 0,
            metrics: CacheMetrics::default(),
        }
    }

    /// Enable/disable the fused serve path (`true` by default). With it off
    /// every miss restores — the seed's behavior, kept for A/B benching.
    pub fn set_fused_enabled(&mut self, enabled: bool) {
        self.fused_enabled = enabled;
    }

    pub fn has_layer(&self, block: usize) -> bool {
        self.layers.contains_key(&block)
    }

    pub fn layer(&self, block: usize) -> Option<&CompressedLayer> {
        self.layers.get(&block)
    }

    /// Bytes of the always-resident compressed representations.
    pub fn compressed_bytes(&self) -> usize {
        self.layers.values().map(|l| l.memory_bytes()).sum()
    }

    /// Bytes of the lazily-built fused state (densified center expert +
    /// split residual pieces per block that has served fused). This is
    /// center-sized, per-layer — NOT per-expert — so it is reported here
    /// rather than charged against the LRU budget, which governs the
    /// per-expert restored set; a deployment sizing memory should add
    /// `compressed_bytes + fused_bytes + budget`.
    pub fn fused_bytes(&self) -> usize {
        self.fused
            .values()
            .filter_map(|f| f.as_ref())
            .map(|f| f.memory_bytes())
            .sum()
    }

    pub fn used_bytes(&self) -> usize {
        self.used_bytes
    }

    /// Fetch (restoring if needed) the expert for `(block, slot)` — the
    /// plain Algorithm-2 path: every miss restores and caches.
    pub fn get(&mut self, block: usize, slot: usize) -> Arc<ExpertWeights> {
        self.clock += 1;
        if let Some(e) = self.hit(block, slot) {
            return e;
        }
        self.metrics.misses += 1;
        self.restore_and_cache(block, slot)
    }

    /// Serve `(block, slot)` for a sub-batch of `batch_tokens` tokens,
    /// choosing between the cached/restored dense expert and the
    /// restore-free fused path per the cost model. Decisions land in
    /// [`CacheMetrics::restore_serves`] / [`CacheMetrics::fused_serves`].
    pub fn serve(&mut self, block: usize, slot: usize, batch_tokens: usize) -> Serve {
        self.clock += 1;
        self.bump_heat((block, slot));
        if let Some(e) = self.hit(block, slot) {
            return Serve::Dense(e);
        }
        self.metrics.misses += 1;
        if self.fused_enabled && !self.should_restore(block, slot, batch_tokens) {
            if let Some(fl) = self.fused_layer(block) {
                self.metrics.fused_serves += 1;
                return Serve::Fused(fl);
            }
        }
        self.metrics.restore_serves += 1;
        Serve::Dense(self.restore_and_cache(block, slot))
    }

    fn hit(&mut self, block: usize, slot: usize) -> Option<Arc<ExpertWeights>> {
        let clock = self.clock;
        let e = self.entries.get_mut(&(block, slot))?;
        e.last_used = clock;
        self.metrics.hits += 1;
        Some(e.expert.clone())
    }

    fn restore_and_cache(&mut self, block: usize, slot: usize) -> Arc<ExpertWeights> {
        let clock = self.clock;
        let t0 = std::time::Instant::now();
        let layer = self.layers.get(&block).expect("block not compressed");
        let restored = Arc::new(layer.restore_expert(slot));
        self.metrics.restore_ns += t0.elapsed().as_nanos() as u64;
        let bytes = expert_bytes(&restored);
        // Evict LRU entries until the new expert fits (a single expert
        // larger than the whole budget is allowed in alone).
        while self.used_bytes + bytes > self.budget_bytes && !self.entries.is_empty() {
            let (&victim, _) = self
                .entries
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .expect("nonempty");
            let removed = self.entries.remove(&victim).unwrap();
            self.used_bytes -= removed.bytes;
            self.metrics.evictions += 1;
        }
        self.used_bytes += bytes;
        self.entries.insert(
            (block, slot),
            Entry { expert: restored.clone(), bytes, last_used: clock },
        );
        restored
    }

    /// The restore-vs-fused cost model (EXPERIMENTS.md §Perf). Restoring
    /// materializes `pI × D` floats once and makes every later hit free;
    /// fused forwards pay O(nnz)/O(rank) extra per call but never touch the
    /// budget. Restore therefore wins iff the dense expert is likely to be
    /// resident when the next request for it arrives — or the current
    /// sub-batch alone amortizes the materialization.
    fn should_restore(&self, block: usize, slot: usize, batch_tokens: usize) -> bool {
        // 1. A large enough sub-batch amortizes the restore immediately.
        if batch_tokens >= RESTORE_AMORTIZE_TOKENS {
            return true;
        }
        let bytes = self.restored_bytes(block, slot);
        // 2. Fits without evicting anyone → it will stick; restore.
        if self.used_bytes + bytes <= self.budget_bytes {
            return true;
        }
        // 3. Larger than the whole budget → guaranteed thrash; stay fused.
        if bytes > self.budget_bytes {
            return false;
        }
        // 4. Tight budget: evict colder residents only for keys with shown
        //    reuse — a cold expert would displace a hotter one just to be
        //    displaced right back.
        self.heat.get(&(block, slot)).copied().unwrap_or(0) >= HOT_ACCESSES
    }

    /// Bytes a restored dense expert for `(block, slot)` would occupy
    /// (pI·D design params + b2), computed without restoring.
    fn restored_bytes(&self, block: usize, slot: usize) -> usize {
        let layer = self.layers.get(&block).expect("block not compressed");
        let e = &layer.experts[layer.expert_map[slot]];
        let (pi, d) = match &e.residual {
            crate::compress::ResidualRepr::Dense(m) => (m.rows, m.cols),
            crate::compress::ResidualRepr::SparseCsr(c) => (c.rows, c.cols),
            crate::compress::ResidualRepr::LowRank(s) => (s.u.rows, s.vt.cols),
        };
        (pi * d + e.b2.len()) * 4
    }

    fn fused_layer(&mut self, block: usize) -> Option<Arc<FusedLayer>> {
        if let Some(f) = self.fused.get(&block) {
            return f.clone();
        }
        let built = self
            .layers
            .get(&block)
            .expect("block not compressed")
            .fused()
            .map(Arc::new);
        self.fused.insert(block, built.clone());
        built
    }

    fn bump_heat(&mut self, key: Key) {
        self.serve_accesses += 1;
        let h = self.heat.entry(key).or_insert(0);
        *h = h.saturating_add(1);
        if self.serve_accesses % HEAT_DECAY_PERIOD == 0 {
            for v in self.heat.values_mut() {
                *v /= 2;
            }
            self.heat.retain(|_, v| *v > 0);
        }
    }

    /// Pre-warm the cache for the given (block, slot) pairs (the scheduler
    /// calls this with router predictions).
    pub fn prefetch(&mut self, keys: &[Key]) {
        for &(b, s) in keys {
            if self.has_layer(b) {
                let _ = self.get(b, s);
            }
        }
    }

    pub fn resident_experts(&self) -> usize {
        self.entries.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::quick_compress;
    use crate::compress::ResMoE;
    use crate::moe::{ExpertArch, MoeLayer};
    use crate::util::Rng;

    fn compressed(seed: u64) -> (MoeLayer, CompressedLayer) {
        let mut rng = Rng::new(seed);
        let l = MoeLayer::random(ExpertArch::Relu, 8, 16, 4, 2, true, false, &mut rng);
        let cl = quick_compress(&ResMoE::up(), &l, 0.25, seed);
        (l, cl)
    }

    fn one_expert_bytes() -> usize {
        // relu p=8 pi=16 → (16*8 + 16 + 8*16 + 8) * 4
        (16 * 8 + 16 + 8 * 16 + 8) * 4
    }

    #[test]
    fn restores_correct_experts() {
        let (l, cl) = compressed(1);
        let mut cache = ExpertCache::new(vec![(3, cl.clone())], usize::MAX);
        for slot in 0..4 {
            let e = cache.get(3, slot);
            let direct = cl.restore_expert(slot);
            assert_eq!(*e, direct);
        }
        let _ = l;
        assert_eq!(cache.metrics.misses, 4);
        assert_eq!(cache.metrics.hits, 0);
    }

    #[test]
    fn hits_after_warm() {
        let (_, cl) = compressed(2);
        let mut cache = ExpertCache::new(vec![(0, cl)], usize::MAX);
        cache.get(0, 1);
        cache.get(0, 1);
        cache.get(0, 1);
        assert_eq!(cache.metrics.hits, 2);
        assert_eq!(cache.metrics.misses, 1);
        assert!(cache.metrics.hit_rate() > 0.6);
    }

    #[test]
    fn budget_forces_eviction_lru_order() {
        let (_, cl) = compressed(3);
        // Budget for exactly two restored experts.
        let mut cache = ExpertCache::new(vec![(0, cl)], 2 * one_expert_bytes());
        cache.get(0, 0);
        cache.get(0, 1);
        assert_eq!(cache.resident_experts(), 2);
        cache.get(0, 0); // refresh 0 → LRU victim is 1
        cache.get(0, 2); // evicts 1
        assert_eq!(cache.metrics.evictions, 1);
        cache.get(0, 0); // still resident → hit
        assert_eq!(cache.metrics.hits, 2);
        cache.get(0, 1); // miss again (was evicted)
        assert_eq!(cache.metrics.misses, 4);
    }

    #[test]
    fn tiny_budget_still_serves() {
        let (_, cl) = compressed(4);
        let mut cache = ExpertCache::new(vec![(0, cl)], 1);
        let e = cache.get(0, 3);
        assert!(e.n_params() > 0);
        assert_eq!(cache.resident_experts(), 1); // single over-budget entry allowed
    }

    #[test]
    fn prefetch_warms() {
        let (_, cl) = compressed(5);
        let mut cache = ExpertCache::new(vec![(2, cl)], usize::MAX);
        cache.prefetch(&[(2, 0), (2, 1), (9, 0)]); // block 9 ignored
        assert_eq!(cache.resident_experts(), 2);
        cache.get(2, 0);
        assert_eq!(cache.metrics.hits, 1);
    }

    #[test]
    fn serve_restores_when_budget_has_room() {
        let (_, cl) = compressed(7);
        let mut cache = ExpertCache::new(vec![(0, cl.clone())], usize::MAX);
        let Serve::Dense(e) = cache.serve(0, 1, 4) else {
            panic!("room in budget must restore")
        };
        assert_eq!(*e, cl.restore_expert(1));
        assert_eq!(cache.metrics.restore_serves, 1);
        assert_eq!(cache.resident_experts(), 1);
        // Second serve is a hit, not a new decision.
        let Serve::Dense(_) = cache.serve(0, 1, 4) else { panic!("hit") };
        assert_eq!(cache.metrics.hits, 1);
        assert_eq!(cache.metrics.restore_serves, 1);
    }

    #[test]
    fn serve_goes_fused_under_thrash_budget() {
        // Budget below one restored expert: every miss must take the fused
        // path and never evict/restore.
        let (_, cl) = compressed(8);
        let budget = one_expert_bytes() / 2;
        let mut cache = ExpertCache::new(vec![(0, cl.clone())], budget);
        let mut rng = Rng::new(1);
        let x = crate::tensor::Matrix::randn(5, 8, 1.0, &mut rng);
        for slot in [0usize, 1, 2, 3, 0, 1] {
            match cache.serve(0, slot, x.rows) {
                Serve::Fused(fl) => {
                    let shared = fl.shared_act(&x);
                    let got = fl.forward_slot(slot, &x, &shared);
                    let want = cl.restore_expert(slot).forward(&x);
                    assert!(got.sq_dist(&want) < 1e-8, "slot {slot}");
                }
                Serve::Dense(_) => panic!("thrash budget must serve fused"),
            }
        }
        assert_eq!(cache.metrics.fused_serves, 6);
        assert_eq!(cache.metrics.restore_serves, 0);
        assert_eq!(cache.metrics.evictions, 0);
        assert_eq!(cache.used_bytes(), 0);
        // The fused state is accounted: roughly one densified center plus
        // the compressed residual pieces, and it is reported, not budgeted.
        let fb = cache.fused_bytes();
        assert!(fb >= one_expert_bytes(), "fused state includes the dense center: {fb}");
        assert!(fb < 4 * one_expert_bytes(), "fused state must stay near compressed size: {fb}");
    }

    #[test]
    fn serve_restores_hot_keys_on_tight_budget() {
        // Budget for one expert, two slots competing: the repeatedly-hit
        // slot earns a restore after HOT_ACCESSES, the cold one stays fused.
        let (_, cl) = compressed(9);
        let mut cache = ExpertCache::new(vec![(0, cl)], one_expert_bytes());
        // Fill the single cache slot with expert 3.
        assert!(matches!(cache.serve(0, 3, 1), Serve::Dense(_)));
        // Expert 0 is cold: first misses go fused...
        assert!(matches!(cache.serve(0, 0, 1), Serve::Fused(_)));
        assert!(matches!(cache.serve(0, 0, 1), Serve::Fused(_)));
        // ...until its heat crosses the threshold and it earns the eviction.
        assert!(matches!(cache.serve(0, 0, 1), Serve::Dense(_)));
        assert_eq!(cache.metrics.evictions, 1);
        assert_eq!(cache.metrics.fused_serves, 2);
        assert_eq!(cache.metrics.restore_serves, 2);
    }

    #[test]
    fn serve_big_batches_restore_even_when_thrashing() {
        let (_, cl) = compressed(10);
        let mut cache = ExpertCache::new(vec![(0, cl)], 1);
        assert!(matches!(cache.serve(0, 2, 4096), Serve::Dense(_)));
        assert_eq!(cache.metrics.restore_serves, 1);
    }

    #[test]
    fn serve_with_fused_disabled_always_restores() {
        let (_, cl) = compressed(11);
        let mut cache = ExpertCache::new(vec![(0, cl)], 1);
        cache.set_fused_enabled(false);
        for slot in 0..4 {
            assert!(matches!(cache.serve(0, slot, 1), Serve::Dense(_)));
        }
        assert_eq!(cache.metrics.restore_serves, 4);
        assert_eq!(cache.metrics.fused_serves, 0);
    }

    #[test]
    fn compressed_bytes_below_restored() {
        let (l, cl) = compressed(6);
        let cache = ExpertCache::new(vec![(0, cl)], usize::MAX);
        assert!(cache.compressed_bytes() < l.expert_params() * 4);
    }
}
