//! Restored-expert LRU cache — the paper's Algorithm 2 ("reconstruct and
//! dynamically load the compressed experts") as a serving-runtime feature —
//! plus the **fused-vs-restore cost model** for cache misses, the
//! **backing-store demand-paging mode**, and the **batched serve window**
//! entry point behind cross-request continuous batching.
//!
//! Resident set: the per-layer barycenter `W_ω` lives inside the
//! [`CompressedLayer`] (always in memory, small); restored dense experts
//! are materialized on router demand into an LRU cache bounded by a byte
//! budget. When the budget is smaller than the full restored model, the
//! cache trades restore latency for memory — exactly the knob the paper's
//! space-efficiency argument is about.
//!
//! A miss no longer has to restore: [`ExpertCache::serve`] can answer with
//! the layer's [`FusedLayer`] instead, scoring tokens straight from the
//! compressed representation. The policy (see `should_restore`): restoring
//! pays a dense materialization once and makes every future hit free, so it
//! wins for experts that will stay resident; the fused path wins when the
//! budget cannot hold the expert anyway (thrash) or the expert is cold.
//! Decisions are recorded in [`CacheMetrics`].
//!
//! **Backing-store mode** ([`ExpertCache::from_store`]): instead of holding
//! every compressed residual in memory, the cache keeps only the per-layer
//! skeletons (center + routing metadata) resident and pages individual
//! expert residual shards in from an `RMES` artifact on demand. Paged
//! shards share the byte budget with restored dense experts and are evicted
//! first (they are cheap to refetch); the fused/restore cost model is
//! unchanged and keyed on the dense-resident bytes alone, so a store-backed
//! engine makes byte-identical serving decisions to a monolithic one under
//! the same request stream. Fused misses answer with [`Serve::Paged`] — the
//! densified center plus the one paged expert's split pieces — so no full
//! [`FusedLayer`] (which would need every shard) is ever built.
//!
//! **Int8 residency tier**: artifacts packed with `--quantize int8` page
//! residual shards that are int8 codes + per-row scales (~¼ the resident
//! bytes; tracked in [`CacheMetrics::quant_shard_fetches`] /
//! [`CacheMetrics::quant_shard_bytes`] / [`CacheMetrics::quant_serves`]).
//! The cost model treats these as cheap-to-keep-paged: a quantized shard
//! earns a dense f32 restore only through shown reuse or an amortizing
//! batch — never on mere budget room, which would trade a small int8
//! resident for a full-size dense one. Stores without quantized shards
//! make byte-identical decisions to previous versions.
//!
//! # Per-block state partitioning (the continuous-batching invariant)
//!
//! All mutable serving state — resident maps, LRU clock, heat counters and
//! their decay clock, and the byte budget itself — is **partitioned per
//! compressed block** ([`BlockState`]); the budget splits into equal
//! per-block shares. Two reasons, one practical, one structural:
//!
//! - Layer access is cyclic (block 1, block 3, block 1, …): under a single
//!   global LRU the coldest entry is always *the block about to be served
//!   next*, so a global pool evicts exactly what the next layer needs.
//!   Per-block shares keep each layer's hot set stable.
//! - Serves of different blocks no longer interact through shared state, so
//!   the cache's decision state machine evolves **identically whether a
//!   window of requests is served request-major (serial: all of request
//!   1's layers, then request 2's) or layer-major (batched: every
//!   request's rows at layer 1, then layer 3)** — within one block both
//!   orders visit the same serve sequence. This commutativity is what
//!   makes cross-request batching bit-identical to serial serving under
//!   every budget, not just roomy/thrash ones; the differential property
//!   test `prop_batched_serve_matches_serial_bit_for_bit` pins it.
//!
//! # Batched windows
//!
//! [`ExpertCache::try_serve_batch`] serves one layer's whole batch window:
//! the caller passes the per-(request, slot) serve sequence in serial
//! (request-major) order and gets one [`Serve`] decision per entry. In the
//! steady-state warm window every key is dense-resident and the entire
//! window is answered in **one metadata critical section** (one
//! decide/reserve per layer per batch, not per request). Cold and mixed
//! windows fall back to an exact serial replay — each entry runs the full
//! decide → materialize → publish protocol, and materializations collapse
//! automatically because the first entry's publish turns the remaining
//! entries for that key into hits (and concurrent windows collapse through
//! the per-key singleflight), so every expert is materialized at most once
//! per window.
//!
//! # Lock discipline (the concurrent serving core)
//!
//! The cache is internally synchronized and shared as a plain
//! `Arc<ExpertCache>`. State splits three ways:
//!
//! - **Immutable after construction** (`layers`, `store`): readable from
//!   any thread with no lock at all — routing metadata, compressed
//!   skeletons, and the artifact handle never change while serving.
//! - **Metadata lock** (`Mutex<CacheState>`): the per-block partitions and
//!   the in-flight table. Critical sections are map lookups and integer
//!   arithmetic only — **no file read, CRC check, zstd decode, or restore
//!   matmul ever runs while this lock is held** (debug builds assert it
//!   via a thread-local lock-held flag). Metrics are NOT behind this lock:
//!   since PR 7 every counter is a lock-free atomic on the engine's
//!   [`crate::obs::Registry`] ([`CacheCounters`]), so recording and
//!   snapshotting ([`ExpertCache::metrics`]) never contend with serving.
//! - **Materialized artifacts** (`Arc<ExpertWeights>`, `Arc<FusedExpert>`,
//!   …): handed out of the lock by clone; readers never contend with the
//!   metadata writers while doing the actual math.
//!
//! Every serve is a three-phase protocol: a short locked *decide/reserve*
//! phase (clock tick, heat bump, hit check, cost-model decision, in-flight
//! reservation), an unlocked *materialize* phase (store fetch + CRC + zstd
//! decode, residual-restore matmuls, fused splits), and a short locked
//! *publish* phase (re-check on reacquire, eviction, insert). Concurrent
//! misses on the same key are collapsed by **per-key singleflight**: the
//! first thread becomes the flight leader and materializes; later threads
//! park on the flight's condvar (NOT on the metadata lock) and receive the
//! same `Arc` the leader published, so N workers cold-missing one expert
//! perform exactly one fetch/decode/restore and all serve bit-identical
//! weights. Dedup traffic is counted in
//! [`CacheMetrics::singleflight_waits`] / [`CacheMetrics::dedup_fetches`] /
//! [`CacheMetrics::publish_races_lost`].
//!
//! For a single-threaded client the protocol degenerates to the old
//! serialized order exactly — decisions, evictions, and metrics are
//! bit-identical (`store_engine_matches_monolithic_engine_bit_for_bit`
//! keeps holding).

use crate::compress::{CompressedExpert, CompressedLayer, FusedExpert, FusedLayer};
use crate::moe::{ExpertWeights, KvPagePool};
use crate::obs::{trace, Counter, Registry};
use crate::store::ExpertStore;
use anyhow::{Context, Result};
use std::cell::Cell;
use std::collections::HashMap;
use std::ops::{Deref, DerefMut};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::Instant;

/// (block index, router slot) — the public prefetch-API key. Inside the
/// per-block partitions dense entries are keyed by slot and paged shards by
/// stored-expert index (identical unless a merge method made `expert_map`
/// non-injective).
type Key = (usize, usize);

#[derive(Debug, Default, Clone)]
pub struct CacheMetrics {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub restore_ns: u64,
    /// Misses answered by restoring + caching a dense expert. Under
    /// concurrency this counts cost-model *decisions*; the number of
    /// restore matmuls actually executed is
    /// [`CacheMetrics::restores_executed`].
    pub restore_serves: u64,
    /// Misses answered restore-free through the fused path.
    pub fused_serves: u64,
    /// Dense restore matmuls actually executed. `restore_serves` counts
    /// decisions; this counts work — singleflight dedup and batched
    /// windows make it the smaller number, and "each expert is
    /// materialized at most once per batch window" is asserted against it.
    pub restores_executed: u64,
    /// Batch windows served through [`ExpertCache::try_serve_batch`].
    pub batch_windows: u64,
    /// Batch windows answered entirely from dense-resident entries inside
    /// a single metadata critical section (the warm fast path).
    pub batch_warm_windows: u64,
    /// Prefetch requests that found the key already resident.
    pub prefetch_hits: u64,
    /// Prefetch requests that had to load (or schedule loading of) the key.
    pub prefetch_misses: u64,
    /// Demand accesses served by an entry a prefetch brought in — the
    /// prefetcher's effectiveness numerator.
    pub prefetch_useful: u64,
    /// Async prefetch results discarded (raced a demand fetch, or the
    /// budget was full of demand-resident bytes).
    pub prefetch_dropped: u64,
    /// Residual shards fetched + decoded from the backing store.
    pub shard_fetches: u64,
    pub shard_fetch_ns: u64,
    /// Decoded bytes of fetched shards.
    pub shard_bytes: u64,
    /// Of [`CacheMetrics::shard_fetches`], the fetches whose decoded
    /// residual is int8-quantized (`q8-*` shard kinds).
    pub quant_shard_fetches: u64,
    /// Of [`CacheMetrics::shard_bytes`], the decoded bytes of quantized
    /// shards (int8 codes + per-row f32 scales).
    pub quant_shard_bytes: u64,
    /// Miss serves (restore and fused/paged decisions alike) answered from
    /// an int8-quantized residual.
    pub quant_serves: u64,
    /// Restore decisions whose residual was int8-quantized — the residency
    /// policy *promoting* a hot quantized slot to a dense f32 resident
    /// (quantized shards stay paged until hot; see `should_restore`). The
    /// traffic harness reads this as its "quant promotions" cache-decision
    /// metric.
    pub quant_promotions: u64,
    /// Paged shards evicted to make room.
    pub shard_evictions: u64,
    /// Serves that parked on another thread's in-flight materialization of
    /// the same artifact (per-key singleflight) instead of redoing it.
    pub singleflight_waits: u64,
    /// Heavy materializations (shard fetch + decode, dense restore, fused
    /// build) avoided because an equivalent one was in flight or had just
    /// published when this serve went to reserve it.
    pub dedup_fetches: u64,
    /// Materializations completed but discarded at publish time because a
    /// racing thread (usually the async prefetcher) published the key
    /// first; the resident copy is served instead (decodes are
    /// bit-identical, so this is bookkeeping, not a correctness event).
    pub publish_races_lost: u64,
    /// Store-fetch failures classified transient (retryable I/O), counted
    /// per failed attempt. Integrity failures (CRC/decode/layout) are NOT
    /// retried and are not counted here.
    pub transient_errors: u64,
    /// Backed-off retries of transient fetch failures inside a singleflight
    /// materialize (waiters share the retried result).
    pub fetch_retries: u64,
    /// Shard quarantine entries: transitions into (or re-entries of) the
    /// quarantined state after `QUARANTINE_THRESHOLD` consecutive
    /// whole-fetch failures. TTL expiry re-probes; success clears.
    pub quarantined_shards: u64,
    /// Serves answered by [`Serve::Degraded`] — the barycenter-only center
    /// path standing in for an unfetchable/quarantined residual (the
    /// paper's rate→0 approximation).
    pub degraded_serves: u64,
    /// Store failures on the *prefetch* path (advisory; never retried,
    /// never degrades anything) — kept separate from demand-path errors.
    pub prefetch_errors: u64,
}

impl CacheMetrics {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Fraction of prefetched entries that later served a demand access.
    pub fn prefetch_usefulness(&self) -> f64 {
        if self.prefetch_misses == 0 {
            0.0
        } else {
            self.prefetch_useful as f64 / self.prefetch_misses as f64
        }
    }
}

/// Atomic twins of every [`CacheMetrics`] field, registered as `cache.*`
/// instruments on the engine's [`crate::obs::Registry`] (PR 7). Recording
/// is a relaxed atomic add on a pre-registered counter — **no lock** — so
/// instrumentation can never extend a metadata critical section, and
/// [`ExpertCache::metrics`] snapshots the counters without touching the
/// cache mutex at all. Counter *values* still evolve exactly as the old
/// mutex-guarded fields did (every increment site is unchanged), which
/// keeps each counter-equality assertion in the PR 3–6 suites intact.
pub(crate) struct CacheCounters {
    hits: Arc<Counter>,
    misses: Arc<Counter>,
    evictions: Arc<Counter>,
    restore_ns: Arc<Counter>,
    restore_serves: Arc<Counter>,
    fused_serves: Arc<Counter>,
    restores_executed: Arc<Counter>,
    batch_windows: Arc<Counter>,
    batch_warm_windows: Arc<Counter>,
    prefetch_hits: Arc<Counter>,
    prefetch_misses: Arc<Counter>,
    prefetch_useful: Arc<Counter>,
    prefetch_dropped: Arc<Counter>,
    shard_fetches: Arc<Counter>,
    shard_fetch_ns: Arc<Counter>,
    shard_bytes: Arc<Counter>,
    quant_shard_fetches: Arc<Counter>,
    quant_shard_bytes: Arc<Counter>,
    quant_serves: Arc<Counter>,
    quant_promotions: Arc<Counter>,
    shard_evictions: Arc<Counter>,
    singleflight_waits: Arc<Counter>,
    dedup_fetches: Arc<Counter>,
    publish_races_lost: Arc<Counter>,
    transient_errors: Arc<Counter>,
    fetch_retries: Arc<Counter>,
    quarantined_shards: Arc<Counter>,
    degraded_serves: Arc<Counter>,
    prefetch_errors: Arc<Counter>,
}

impl CacheCounters {
    fn new(reg: &Registry) -> CacheCounters {
        CacheCounters {
            hits: reg.counter("cache.hits"),
            misses: reg.counter("cache.misses"),
            evictions: reg.counter("cache.evictions"),
            restore_ns: reg.counter("cache.restore_ns"),
            restore_serves: reg.counter("cache.restore_serves"),
            fused_serves: reg.counter("cache.fused_serves"),
            restores_executed: reg.counter("cache.restores_executed"),
            batch_windows: reg.counter("cache.batch_windows"),
            batch_warm_windows: reg.counter("cache.batch_warm_windows"),
            prefetch_hits: reg.counter("cache.prefetch_hits"),
            prefetch_misses: reg.counter("cache.prefetch_misses"),
            prefetch_useful: reg.counter("cache.prefetch_useful"),
            prefetch_dropped: reg.counter("cache.prefetch_dropped"),
            shard_fetches: reg.counter("cache.shard_fetches"),
            shard_fetch_ns: reg.counter("cache.shard_fetch_ns"),
            shard_bytes: reg.counter("cache.shard_bytes"),
            quant_shard_fetches: reg.counter("cache.quant_shard_fetches"),
            quant_shard_bytes: reg.counter("cache.quant_shard_bytes"),
            quant_serves: reg.counter("cache.quant_serves"),
            quant_promotions: reg.counter("cache.quant_promotions"),
            shard_evictions: reg.counter("cache.shard_evictions"),
            singleflight_waits: reg.counter("cache.singleflight_waits"),
            dedup_fetches: reg.counter("cache.dedup_fetches"),
            publish_races_lost: reg.counter("cache.publish_races_lost"),
            transient_errors: reg.counter("cache.transient_errors"),
            fetch_retries: reg.counter("cache.fetch_retries"),
            quarantined_shards: reg.counter("cache.quarantined_shards"),
            degraded_serves: reg.counter("cache.degraded_serves"),
            prefetch_errors: reg.counter("cache.prefetch_errors"),
        }
    }

    /// Read every counter into the plain [`CacheMetrics`] snapshot struct.
    /// Lock-free: each field is one relaxed load.
    fn snapshot(&self) -> CacheMetrics {
        CacheMetrics {
            hits: self.hits.get(),
            misses: self.misses.get(),
            evictions: self.evictions.get(),
            restore_ns: self.restore_ns.get(),
            restore_serves: self.restore_serves.get(),
            fused_serves: self.fused_serves.get(),
            restores_executed: self.restores_executed.get(),
            batch_windows: self.batch_windows.get(),
            batch_warm_windows: self.batch_warm_windows.get(),
            prefetch_hits: self.prefetch_hits.get(),
            prefetch_misses: self.prefetch_misses.get(),
            prefetch_useful: self.prefetch_useful.get(),
            prefetch_dropped: self.prefetch_dropped.get(),
            shard_fetches: self.shard_fetches.get(),
            shard_fetch_ns: self.shard_fetch_ns.get(),
            shard_bytes: self.shard_bytes.get(),
            quant_shard_fetches: self.quant_shard_fetches.get(),
            quant_shard_bytes: self.quant_shard_bytes.get(),
            quant_serves: self.quant_serves.get(),
            quant_promotions: self.quant_promotions.get(),
            shard_evictions: self.shard_evictions.get(),
            singleflight_waits: self.singleflight_waits.get(),
            dedup_fetches: self.dedup_fetches.get(),
            publish_races_lost: self.publish_races_lost.get(),
            transient_errors: self.transient_errors.get(),
            fetch_retries: self.fetch_retries.get(),
            quarantined_shards: self.quarantined_shards.get(),
            degraded_serves: self.degraded_serves.get(),
            prefetch_errors: self.prefetch_errors.get(),
        }
    }
}

/// How [`ExpertCache::serve`] answers a lookup. `Clone` is cheap (`Arc`s)
/// so batched windows can hand one decision to several dispatch segments.
#[derive(Clone)]
pub enum Serve {
    /// Dense weights: a cache hit, or a miss the policy chose to restore
    /// (and cache).
    Dense(Arc<ExpertWeights>),
    /// Restore-free: forward through [`FusedLayer::forward_slot`].
    Fused(Arc<FusedLayer>),
    /// Restore-free in backing-store mode: the densified center plus the
    /// single paged expert — forward through
    /// [`crate::compress::fused_forward_expert`] with a
    /// [`crate::compress::center_shared_act`] shared term.
    Paged { center: Arc<ExpertWeights>, expert: Arc<FusedExpert> },
    /// Fault-degraded store-mode answer: the residual shard was
    /// quarantined or unfetchable, so the slot is served by the shared
    /// barycenter center alone — the rate→0 limit of the paper's
    /// `expert ≈ barycenter + residual` approximation. Approximate, never
    /// silent: the server marks these responses [`super::Response::Degraded`].
    Degraded(Arc<ExpertWeights>),
}

impl Serve {
    /// Whether two serves dispatch through the exact same weight objects —
    /// the batched hook fuses adjacent per-request row segments whose
    /// serves agree (row-independent kernels make the combined matmul
    /// bit-identical to per-request ones).
    pub fn same_source(&self, other: &Serve) -> bool {
        match (self, other) {
            (Serve::Dense(a), Serve::Dense(b)) => Arc::ptr_eq(a, b),
            (Serve::Fused(a), Serve::Fused(b)) => Arc::ptr_eq(a, b),
            (
                Serve::Paged { center: ca, expert: ea },
                Serve::Paged { center: cb, expert: eb },
            ) => Arc::ptr_eq(ca, cb) && Arc::ptr_eq(ea, eb),
            (Serve::Degraded(a), Serve::Degraded(b)) => Arc::ptr_eq(a, b),
            _ => false,
        }
    }
}

// ------------------------------------------------- fault classification

/// How a store-path failure should be handled.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorClass {
    /// Retryable I/O (read errors, short reads, injected transients):
    /// worth a bounded backed-off retry before giving up.
    Transient,
    /// The bytes came back but are wrong (CRC mismatch, zstd failure,
    /// length/layout disagreement): retrying re-reads the same bad bytes,
    /// so fail fast and let quarantine + degradation take over.
    Integrity,
}

/// Classify a formatted store/cache error chain. Substring-matching the
/// message is deliberate: errors cross singleflight flights as strings
/// (`anyhow::Error` is not `Clone`), so the string IS the wire format.
pub fn classify_error(msg: &str) -> ErrorClass {
    const INTEGRITY: [&str; 4] =
        ["checksum mismatch", "decompression failed", "index says", "bad shard payload"];
    if INTEGRITY.iter().any(|m| msg.contains(m)) {
        ErrorClass::Integrity
    } else {
        ErrorClass::Transient
    }
}

/// Consecutive whole-fetch failures (each already retried up to
/// [`FETCH_RETRY_LIMIT`] times) before a shard enters quarantine.
const QUARANTINE_THRESHOLD: u32 = 3;
/// Base quarantine TTL; doubles on every failed re-probe (hysteresis so a
/// genuinely dead shard costs one probe per widening window, not a flap).
const QUARANTINE_TTL: std::time::Duration = std::time::Duration::from_millis(250);
/// Cap on the TTL doubling (2^6 · 250ms = 16s between probes).
const QUARANTINE_MAX_SPELLS: u32 = 6;
/// Transient-failure retries per fetch, inside the singleflight
/// materialize step — waiters share the retried result.
const FETCH_RETRY_LIMIT: u32 = 3;
/// Backoff before retry k (1-based) is `FETCH_BACKOFF · 2^(k-1)`.
const FETCH_BACKOFF: std::time::Duration = std::time::Duration::from_micros(50);

/// Per-shard failure bookkeeping (store mode, keyed by stored-expert
/// index). Absent from the map = healthy; success removes the entry, so
/// with faults never firing this table stays empty and costs nothing.
struct ShardHealth {
    /// Whole-fetch failures in a row (retry budget already spent on each).
    consecutive_failures: u32,
    /// While `Instant::now()` is before this, serves skip the store and
    /// degrade immediately; after it, the next serve is the half-open
    /// probe (singleflight guarantees there is exactly one prober).
    quarantined_until: Option<Instant>,
    /// Completed quarantine spells — the TTL-doubling exponent.
    spells: u32,
}

struct Entry {
    expert: Arc<ExpertWeights>,
    bytes: usize,
    /// LRU stamp (monotone per-block counter).
    last_used: u64,
    /// Brought in by a prefetch and not yet demanded.
    from_prefetch: bool,
}

struct ShardEntry {
    expert: Arc<CompressedExpert>,
    /// Lazily-split fused pieces for the paged serve path.
    fused: Option<Arc<FusedExpert>>,
    bytes: usize,
    last_used: u64,
    from_prefetch: bool,
}

// --------------------------------------------------------------- flights

/// What a singleflight materializes. One key per distinct heavy artifact:
/// flights only ever depend on flights strictly later in this list
/// (`Dense`/`FusedShard` lead a nested `Shard` flight), so waiting cannot
/// cycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
enum FlightKey {
    /// Restored dense expert for (block, slot).
    Dense(usize, usize),
    /// Split fused pieces of a paged shard (block, expert index).
    FusedShard(usize, usize),
    /// Monolithic-mode fused layer build for a block.
    FusedLayer(usize),
    /// Store-mode densified center for a block.
    Center(usize),
    /// Fetched + decoded compressed shard for (block, expert index).
    Shard(usize, usize),
}

/// The leader's published result, cloned out to every waiter. `Arc`s make
/// the clone trivial; errors cross as strings because `anyhow::Error` is
/// not `Clone`.
#[derive(Clone)]
enum FlightPayload {
    Dense(Arc<ExpertWeights>),
    FusedShard(Arc<FusedExpert>),
    FusedLayer(Option<Arc<FusedLayer>>),
    Center(Option<Arc<ExpertWeights>>),
    Shard(Arc<CompressedExpert>),
}

type FlightResult = std::result::Result<FlightPayload, String>;

/// One in-flight materialization. Waiters park on the condvar — never on
/// the cache metadata lock — until the leader fulfills.
struct Flight {
    slot: Mutex<Option<FlightResult>>,
    cv: Condvar,
}

impl Flight {
    fn new() -> Flight {
        Flight { slot: Mutex::new(None), cv: Condvar::new() }
    }

    fn wait(&self) -> FlightResult {
        let mut g = self.slot.lock().unwrap();
        while g.is_none() {
            g = self.cv.wait(g).unwrap();
        }
        g.clone().expect("checked above")
    }

    fn fulfill(&self, r: FlightResult) {
        *self.slot.lock().unwrap() = Some(r);
        self.cv.notify_all();
    }
}

/// The leader's claim on a flight. Dropping an armed lease (leader
/// panicked in its materialize phase, or bailed through `?`) unregisters
/// the flight and wakes every waiter with an error, so nobody parks
/// forever behind a dead leader.
struct FlightLease<'a> {
    cache: &'a ExpertCache,
    key: FlightKey,
    flight: Arc<Flight>,
    armed: bool,
}

impl FlightLease<'_> {
    /// Publish under the caller's already-held metadata guard: unregister
    /// the flight and hand the result to the waiters.
    fn complete(mut self, st: &mut CacheState, payload: FlightResult) {
        st.flights.remove(&self.key);
        self.armed = false;
        self.flight.fulfill(payload);
    }
}

impl Drop for FlightLease<'_> {
    fn drop(&mut self) {
        if self.armed {
            self.cache.lock_state().flights.remove(&self.key);
            self.flight.fulfill(Err(format!("{:?}: leader aborted", self.key)));
        }
    }
}

// -------------------------------------------------- metadata lock guard

thread_local! {
    /// True while THIS thread holds a cache metadata lock — the debug
    /// tripwire behind `assert_unlocked`.
    static STATE_LOCK_HELD: Cell<bool> = const { Cell::new(false) };
}

/// Debug-mode guard for the whole-point invariant of this module: heavy
/// work (file reads, CRC, zstd decode, restore matmuls, fused splits) must
/// never run while the cache metadata lock is held.
fn assert_unlocked(what: &str) {
    if cfg!(debug_assertions) {
        STATE_LOCK_HELD.with(|f| {
            assert!(!f.get(), "{what} must not run under the cache metadata lock");
        });
    }
}

struct StateGuard<'a>(MutexGuard<'a, CacheState>);

impl Deref for StateGuard<'_> {
    type Target = CacheState;
    fn deref(&self) -> &CacheState {
        &self.0
    }
}

impl DerefMut for StateGuard<'_> {
    fn deref_mut(&mut self) -> &mut CacheState {
        &mut self.0
    }
}

impl Drop for StateGuard<'_> {
    fn drop(&mut self) {
        STATE_LOCK_HELD.with(|f| f.set(false));
    }
}

// ---------------------------------------------------- per-block partition

/// One compressed block's mutable serving state. Everything a serve of
/// this block reads or writes lives here (plus the global metrics), so
/// serves of different blocks commute — the invariant the batched-serving
/// parity proof rests on (see the module docs).
struct BlockState {
    /// slot → restored dense expert.
    entries: HashMap<usize, Entry>,
    /// Store mode: expert index → paged residual shard.
    shards: HashMap<usize, ShardEntry>,
    /// Monolithic mode: lazily built fused layer (`Some(None)` = the layer
    /// has no shared center).
    fused: Option<Option<Arc<FusedLayer>>>,
    /// Store mode: lazily densified center.
    fused_center: Option<Option<Arc<ExpertWeights>>>,
    /// Decayed per-slot access counts driving the restore-vs-fused choice.
    heat: HashMap<usize, u32>,
    /// serve() calls against this block — the decay clock for `heat`.
    /// Deliberately NOT the LRU `clock` (which get()/prefetch() also
    /// advance): decay must tick every HEAT_DECAY_PERIOD serves regardless
    /// of interleaving.
    serve_accesses: u64,
    /// Cumulative (never decayed) per-slot serve counts — the routing-skew
    /// census the traffic harness reads via [`ExpertCache::slot_serves`].
    /// Unlike `heat` this is pure bookkeeping: no serving decision reads it.
    serves_by_slot: HashMap<usize, u64>,
    /// This block's equal share of the cache byte budget.
    budget_bytes: usize,
    used_bytes: usize,
    shard_used_bytes: usize,
    /// LRU clock (monotone, per block).
    clock: u64,
    /// Store mode: stored-expert index → failure/quarantine state. Empty
    /// unless fetches have actually failed.
    health: HashMap<usize, ShardHealth>,
}

impl BlockState {
    fn new(budget_bytes: usize) -> BlockState {
        BlockState {
            entries: HashMap::new(),
            shards: HashMap::new(),
            fused: None,
            fused_center: None,
            heat: HashMap::new(),
            serve_accesses: 0,
            serves_by_slot: HashMap::new(),
            budget_bytes,
            used_bytes: 0,
            shard_used_bytes: 0,
            clock: 0,
            health: HashMap::new(),
        }
    }

    fn hit(&mut self, slot: usize, c: &CacheCounters) -> Option<Arc<ExpertWeights>> {
        let e = self.touch_dense_entry(slot, true, c)?;
        c.hits.inc();
        Some(e)
    }

    /// Refresh + hand out a resident dense entry (LRU stamp at the current
    /// clock); `demand` marks prefetched entries useful.
    fn touch_dense_entry(
        &mut self,
        slot: usize,
        demand: bool,
        c: &CacheCounters,
    ) -> Option<Arc<ExpertWeights>> {
        let clock = self.clock;
        let e = self.entries.get_mut(&slot)?;
        e.last_used = clock;
        if demand && e.from_prefetch {
            e.from_prefetch = false;
            c.prefetch_useful.inc();
        }
        Some(e.expert.clone())
    }

    /// Shard-pool analog of [`BlockState::touch_dense_entry`].
    fn touch_shard_entry(
        &mut self,
        eidx: usize,
        demand: bool,
        c: &CacheCounters,
    ) -> Option<Arc<CompressedExpert>> {
        let clock = self.clock;
        let s = self.shards.get_mut(&eidx)?;
        s.last_used = clock;
        if demand && s.from_prefetch {
            s.from_prefetch = false;
            c.prefetch_useful.inc();
        }
        Some(s.expert.clone())
    }

    /// Hand out the already-split fused pieces of a resident shard, with
    /// demand-access bookkeeping.
    fn touch_fused_shard(
        &mut self,
        eidx: usize,
        c: &CacheCounters,
    ) -> Option<Arc<FusedExpert>> {
        let clock = self.clock;
        let s = self.shards.get_mut(&eidx)?;
        let f = s.fused.clone()?;
        s.last_used = clock;
        if s.from_prefetch {
            s.from_prefetch = false;
            c.prefetch_useful.inc();
        }
        Some(f)
    }

    /// Attach freshly-split fused pieces to their (still-resident) shard
    /// entry, charging the extra bytes to the pool.
    fn publish_fused_split(
        &mut self,
        eidx: usize,
        fused: &Arc<FusedExpert>,
        extra: usize,
        c: &CacheCounters,
    ) {
        match self.shards.get_mut(&eidx) {
            Some(s) if s.fused.is_none() => {
                s.fused = Some(fused.clone());
                s.bytes += extra;
                self.shard_used_bytes += extra;
                self.trim_shards(c);
            }
            // Another path filled the pieces first; keep theirs.
            Some(_) => c.publish_races_lost.inc(),
            // The shard was evicted between fetch and split (tight budget
            // under concurrent pressure): serve the pieces uncached rather
            // than resurrect an evicted entry.
            None => {}
        }
    }

    fn bump_heat(&mut self, slot: usize) {
        self.serve_accesses += 1;
        *self.serves_by_slot.entry(slot).or_insert(0) += 1;
        let h = self.heat.entry(slot).or_insert(0);
        *h = h.saturating_add(1);
        if self.serve_accesses % HEAT_DECAY_PERIOD == 0 {
            for v in self.heat.values_mut() {
                *v /= 2;
            }
            self.heat.retain(|_, v| *v > 0);
        }
    }

    /// Evict LRU dense entries until `bytes` more fit (a single expert
    /// larger than the whole share is allowed in alone). Only dense
    /// residents count here — paged shards are trimmed separately so the
    /// dense working set evolves identically to monolithic mode.
    fn evict_dense_until_fits(&mut self, bytes: usize, c: &CacheCounters) {
        while self.used_bytes + bytes > self.budget_bytes && !self.entries.is_empty() {
            let (&victim, _) = self
                .entries
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .expect("nonempty");
            let removed = self.entries.remove(&victim).unwrap();
            self.used_bytes -= removed.bytes;
            c.evictions.inc();
        }
    }

    /// Evict paged shards (LRU) until dense + paged fit the share.
    fn trim_shards(&mut self, c: &CacheCounters) {
        while self.used_bytes + self.shard_used_bytes > self.budget_bytes
            && !self.shards.is_empty()
        {
            self.evict_lru_shard(c);
        }
    }

    fn evict_lru_shard(&mut self, c: &CacheCounters) {
        let victim = self
            .shards
            .iter()
            .min_by_key(|(_, e)| e.last_used)
            .map(|(&k, _)| k);
        if let Some(victim) = victim {
            let removed = self.shards.remove(&victim).unwrap();
            self.shard_used_bytes -= removed.bytes;
            c.shard_evictions.inc();
        }
    }

    /// Make room among the paged shards for `bytes` more (never evicts
    /// dense residents — they are the hot set the cost model chose).
    fn make_room_for_shard(&mut self, bytes: usize, c: &CacheCounters) {
        while self.used_bytes + self.shard_used_bytes + bytes > self.budget_bytes
            && !self.shards.is_empty()
        {
            self.evict_lru_shard(c);
        }
    }

    /// Refresh the LRU stamp of a resident key without counting a demand
    /// hit (the prefetch paths).
    fn touch_key(&mut self, slot: usize, eidx: Option<usize>) {
        let clock = self.clock;
        if let Some(e) = self.entries.get_mut(&slot) {
            e.last_used = clock;
            return;
        }
        if let Some(eidx) = eidx {
            if let Some(s) = self.shards.get_mut(&eidx) {
                s.last_used = clock;
            }
        }
    }
}

// ------------------------------------------------------------ the cache

/// Everything mutable, behind the short metadata lock: the per-block
/// partitions plus the global singleflight table. Methods here run
/// exclusively inside critical sections — keep them to map operations and
/// integer arithmetic. Metrics live OUTSIDE this struct since PR 7: they
/// are lock-free atomics in [`CacheCounters`], recorded from inside and
/// outside critical sections alike without affecting their length.
struct CacheState {
    blocks: HashMap<usize, BlockState>,
    /// Master switch for the fused path (benches compare both policies).
    fused_enabled: bool,
    /// Per-key singleflight table: reserved materializations in progress.
    flights: HashMap<FlightKey, Arc<Flight>>,
}

impl CacheState {
    fn block_mut(&mut self, block: usize) -> &mut BlockState {
        self.blocks.get_mut(&block).expect("block not compressed")
    }
}

/// LRU cache of restored experts over a set of compressed layers, with an
/// optional backing artifact store for the residual shards. Internally
/// synchronized — share as `Arc<ExpertCache>` and call from any thread
/// (see the module docs for the lock discipline).
pub struct ExpertCache {
    /// Immutable after construction — lock-free reads from any thread.
    layers: HashMap<usize, CompressedLayer>,
    /// Backing store (None = monolithic mode: every residual in memory).
    store: Option<Arc<ExpertStore>>,
    state: Mutex<CacheState>,
    /// The engine-wide metrics registry this cache's counters live on.
    /// Outside the mutex: recording and snapshotting never lock.
    obs: Arc<Registry>,
    counters: CacheCounters,
    /// Shared KV page pool for decode sequences, sized at one extra
    /// per-block share of the cache budget. Leases are admission-time
    /// reservations (never revoked mid-sequence), so KV growth can refuse
    /// new sequences but can never evict a live one — the dense/shard
    /// pools keep their full per-block shares untouched.
    kv_pool: Arc<KvPagePool>,
}

fn expert_bytes(e: &ExpertWeights) -> usize {
    e.n_params() * 4
}

/// Equal share of the total cache budget per compressed block. The
/// partition (vs one global pool) is deliberate — see the module docs:
/// cyclic layer access makes a global LRU evict exactly the block about to
/// be served, and independent per-block state is what makes batched
/// (layer-major) serving commute with serial (request-major) serving.
fn per_block_budget(total: usize, n_blocks: usize) -> usize {
    total / n_blocks.max(1)
}

/// Accesses in the decay window after which a key counts as hot enough to
/// evict colder residents for (see `should_restore`).
const HOT_ACCESSES: u32 = 3;
/// Halve every heat counter each time this many accesses elapse, so "hot"
/// tracks the recent request mix rather than all of history.
const HEAT_DECAY_PERIOD: u64 = 256;
/// Sub-batches at least this large amortize a restore within the single
/// call, so restore regardless of heat. Since PR 10 batched windows apply
/// this to the COMBINED window's token count, not each request's own
/// sub-batch: the restore is paid once per window, so the whole window's
/// rows amortize it. This deliberately diverges from the serial reference
/// (a serial loop sees only its own rows) — the relaxed-parity harness
/// (`prop_decode`) covers the divergence with decision-counter
/// conservation laws instead of bit-for-bit decision equality.
const RESTORE_AMORTIZE_TOKENS: usize = 512;

impl ExpertCache {
    pub fn new(layers: Vec<(usize, CompressedLayer)>, budget_bytes: usize) -> ExpertCache {
        Self::build(layers.into_iter().collect(), None, budget_bytes)
    }

    /// Backing-store mode: load only the per-layer skeletons (center +
    /// routing metadata) eagerly; every residual shard pages in on demand
    /// through [`ExpertCache::serve`] / [`ExpertCache::prefetch`].
    pub fn from_store(store: Arc<ExpertStore>, budget_bytes: usize) -> Result<ExpertCache> {
        let mut layers = HashMap::new();
        for block in store.blocks() {
            let skeleton = store
                .load_layer_skeleton(block)
                .with_context(|| format!("load skeleton for block {block}"))?;
            layers.insert(block, skeleton);
        }
        Ok(Self::build(layers, Some(store), budget_bytes))
    }

    fn build(
        layers: HashMap<usize, CompressedLayer>,
        store: Option<Arc<ExpertStore>>,
        budget_bytes: usize,
    ) -> ExpertCache {
        let share = per_block_budget(budget_bytes, layers.len());
        let blocks = layers.keys().map(|&b| (b, BlockState::new(share))).collect();
        let obs = Arc::new(Registry::new());
        let counters = CacheCounters::new(&obs);
        ExpertCache {
            layers,
            store,
            state: Mutex::new(CacheState {
                blocks,
                fused_enabled: true,
                flights: HashMap::new(),
            }),
            obs,
            counters,
            kv_pool: Arc::new(KvPagePool::new(share)),
        }
    }

    /// The metrics registry this cache's `cache.*` counters are registered
    /// on. The engine hangs its `server.*`/`batch.*` instruments off the
    /// same registry so one snapshot covers the whole serving stack.
    pub fn registry(&self) -> &Arc<Registry> {
        &self.obs
    }

    /// The KV page pool decode sequences lease from. Sized at one
    /// per-block share of the cache budget, in ADDITION to the dense and
    /// shard partitions — KV pressure refuses new sequences rather than
    /// shrinking the expert working set mid-flight.
    pub fn kv_pool(&self) -> &Arc<KvPagePool> {
        &self.kv_pool
    }

    fn lock_state(&self) -> StateGuard<'_> {
        STATE_LOCK_HELD
            .with(|f| debug_assert!(!f.get(), "cache metadata lock is not re-entrant"));
        let g = self.state.lock().unwrap();
        STATE_LOCK_HELD.with(|f| f.set(true));
        StateGuard(g)
    }

    /// The backing store, when in store mode.
    pub fn backing_store(&self) -> Option<&Arc<ExpertStore>> {
        self.store.as_ref()
    }

    /// Enable/disable the fused serve path (`true` by default). With it off
    /// every miss restores — the seed's behavior, kept for A/B benching.
    pub fn set_fused_enabled(&self, enabled: bool) {
        self.lock_state().fused_enabled = enabled;
    }

    pub fn has_layer(&self, block: usize) -> bool {
        self.layers.contains_key(&block)
    }

    pub fn layer(&self, block: usize) -> Option<&CompressedLayer> {
        self.layers.get(&block)
    }

    /// Stored-expert index behind router slot `slot` of `block`.
    pub fn expert_index(&self, block: usize, slot: usize) -> Option<usize> {
        self.layers.get(&block)?.expert_map.get(slot).copied()
    }

    /// Whether a demand access for `(block, slot)` would be answered from
    /// memory (dense-restored entry, or paged shard in store mode).
    pub fn is_resident(&self, block: usize, slot: usize) -> bool {
        let st = self.lock_state();
        let Some(bs) = st.blocks.get(&block) else { return false };
        if bs.entries.contains_key(&slot) {
            return true;
        }
        match self.expert_index(block, slot) {
            Some(eidx) => bs.shards.contains_key(&eidx),
            None => false,
        }
    }

    /// A snapshot of the counters. Lock-free since PR 7: reads the atomic
    /// registry counters, never the metadata mutex — callable from any
    /// thread (even one holding the metadata lock) without blocking a
    /// serve. Each counter is exact; the set is a relaxed cross-section
    /// (exactly consistent once recording threads are quiesced, which is
    /// when every test reads it).
    pub fn metrics(&self) -> CacheMetrics {
        self.counters.snapshot()
    }

    /// Cumulative per-slot serve counts: `(block, slot, serves)` sorted by
    /// `(block, slot)` for deterministic iteration. Unlike the decayed
    /// `heat` map this census never forgets, so the traffic harness can
    /// check that a Zipf-routed workload's skew actually reaches the cache
    /// (top-decile slots absorbing a super-proportional serve share).
    /// Takes the metadata lock briefly; no serving decision depends on it.
    pub fn slot_serves(&self) -> Vec<(usize, usize, u64)> {
        let st = self.lock_state();
        let mut out: Vec<(usize, usize, u64)> = st
            .blocks
            .iter()
            .flat_map(|(&b, bs)| {
                bs.serves_by_slot.iter().map(move |(&s, &n)| (b, s, n))
            })
            .collect();
        out.sort_unstable();
        out
    }

    /// Count an async-prefetch result that had to be discarded before it
    /// reached [`ExpertCache::insert_prefetched`] (raced a demand fetch, or
    /// the budget was full) — keeps the prefetcher's books honest.
    /// Lock-free.
    pub(crate) fn note_prefetch_dropped(&self) {
        self.counters.prefetch_dropped.inc();
    }

    /// Count a prefetch whose *store fetch itself* failed — kept separate
    /// from demand-path error counters (and from `prefetch_dropped`, which
    /// means "fetched fine, discarded anyway") so fault dashboards can tell
    /// advisory losses from serving-path trouble. Also counted as a drop:
    /// the scheduled load never landed. Lock-free.
    pub(crate) fn note_prefetch_error(&self) {
        self.counters.prefetch_errors.inc();
        self.counters.prefetch_dropped.inc();
    }

    /// Bytes of the always-resident compressed representations (store mode:
    /// just the skeletons — centers + routing metadata).
    pub fn compressed_bytes(&self) -> usize {
        self.layers.values().map(|l| l.memory_bytes()).sum()
    }

    /// Bytes of the lazily-built fused state (densified center expert +
    /// split residual pieces per block that has served fused). This is
    /// center-sized, per-layer — NOT per-expert — so it is reported here
    /// rather than charged against the LRU budget, which governs the
    /// per-expert restored set; a deployment sizing memory should add
    /// `compressed_bytes + fused_bytes + budget`.
    pub fn fused_bytes(&self) -> usize {
        let st = self.lock_state();
        st.blocks
            .values()
            .map(|bs| {
                let monolithic = bs
                    .fused
                    .as_ref()
                    .and_then(|f| f.as_ref())
                    .map(|f| f.memory_bytes())
                    .unwrap_or(0);
                let center = bs
                    .fused_center
                    .as_ref()
                    .and_then(|c| c.as_ref())
                    .map(|c| c.n_params() * 4)
                    .unwrap_or(0);
                monolithic + center
            })
            .sum()
    }

    pub fn used_bytes(&self) -> usize {
        self.lock_state().blocks.values().map(|bs| bs.used_bytes).sum()
    }

    /// Bytes of paged residual shards currently resident (store mode).
    pub fn paged_bytes(&self) -> usize {
        self.lock_state().blocks.values().map(|bs| bs.shard_used_bytes).sum()
    }

    pub fn resident_experts(&self) -> usize {
        self.lock_state().blocks.values().map(|bs| bs.entries.len()).sum()
    }

    /// Paged shards currently resident (store mode).
    pub fn resident_shards(&self) -> usize {
        self.lock_state().blocks.values().map(|bs| bs.shards.len()).sum()
    }

    /// Live singleflight flights — the chaos suite's lease-leak detector:
    /// after every client thread has joined, this must be zero no matter
    /// how many leaders failed or aborted.
    #[doc(hidden)]
    pub fn debug_flight_count(&self) -> usize {
        self.lock_state().flights.len()
    }

    /// Fetch (restoring if needed) the expert for `(block, slot)` — the
    /// plain Algorithm-2 path: every miss restores and caches. Fallible in
    /// store mode (fetch/integrity errors); infallible monolithic.
    pub fn try_get(&self, block: usize, slot: usize) -> Result<Arc<ExpertWeights>> {
        {
            let mut st = self.lock_state();
            let bs = st.block_mut(block);
            bs.clock += 1;
            if let Some(e) = bs.hit(slot, &self.counters) {
                return Ok(e);
            }
            self.counters.misses.inc();
        }
        self.restore_and_cache(block, slot, false)
    }

    /// Panicking [`ExpertCache::try_get`] — test-only convenience.
    #[cfg(test)]
    pub(crate) fn get(&self, block: usize, slot: usize) -> Arc<ExpertWeights> {
        self.try_get(block, slot).expect("expert shard fetch failed")
    }

    /// Panicking [`ExpertCache::try_serve`] — test-only convenience for
    /// suites that assert on the decision, not the failure handling.
    #[cfg(test)]
    pub(crate) fn serve(&self, block: usize, slot: usize, batch_tokens: usize) -> Serve {
        self.try_serve(block, slot, batch_tokens).expect("expert shard fetch failed")
    }

    /// Serve `(block, slot)` for a sub-batch of `batch_tokens` tokens,
    /// choosing between the cached/restored dense expert and the
    /// restore-free fused path per the cost model. Decisions land in
    /// [`CacheMetrics::restore_serves`] / [`CacheMetrics::fused_serves`].
    ///
    /// Phase 1 (locked): clock tick, heat bump, hit check, cost-model
    /// decision. Phases 2–3 (materialize + publish) run in the singleflight
    /// helpers below, outside the metadata lock.
    ///
    /// Store mode degrades instead of failing where the math allows it: if
    /// the residual shard cannot be fetched (quarantined, exhausted its
    /// transient-retry budget, or integrity-bad) but the barycenter center
    /// IS available, the serve answers [`Serve::Degraded`] — approximate
    /// output beats a failed request, and the server marks it so clients
    /// can tell. Only when the center itself is unavailable does the error
    /// propagate.
    pub fn try_serve(&self, block: usize, slot: usize, batch_tokens: usize) -> Result<Serve> {
        self.try_serve_amortized(block, slot, batch_tokens)
    }

    /// [`ExpertCache::try_serve`] with an explicit amortization basis:
    /// `amortize_tokens` is the row count the cost model's
    /// [`RESTORE_AMORTIZE_TOKENS`] rule sees. Serial serves pass their own
    /// `batch_tokens`; batched windows pass the combined window total so a
    /// restore paid once per window is amortized over every row that
    /// benefits from it.
    fn try_serve_amortized(
        &self,
        block: usize,
        slot: usize,
        amortize_tokens: usize,
    ) -> Result<Serve> {
        let wants_fused = {
            let mut st = self.lock_state();
            let fused_enabled = st.fused_enabled;
            let bs = st.block_mut(block);
            bs.clock += 1;
            bs.bump_heat(slot);
            if let Some(e) = bs.hit(slot, &self.counters) {
                return Ok(Serve::Dense(e));
            }
            self.counters.misses.inc();
            fused_enabled && !self.should_restore(bs, block, slot, amortize_tokens)
        };
        let quant = self.slot_is_quantized(block, slot) as u64;
        if wants_fused {
            if self.store.is_some() {
                if let Some(center) = self.fused_center(block) {
                    match self.fused_shard_expert(block, slot) {
                        Ok(expert) => {
                            self.counters.fused_serves.inc();
                            self.counters.quant_serves.add(quant);
                            return Ok(Serve::Paged { center, expert });
                        }
                        Err(e) => return self.degrade(block, slot, Some(center), e),
                    }
                }
            } else if let Some(fl) = self.fused_layer(block) {
                self.counters.fused_serves.inc();
                self.counters.quant_serves.add(quant);
                return Ok(Serve::Fused(fl));
            }
        }
        self.counters.restore_serves.inc();
        self.counters.quant_serves.add(quant);
        // A restore decision over a quantized residual is the residency
        // policy promoting a hot quantized slot to a dense f32 resident.
        self.counters.quant_promotions.add(quant);
        match self.restore_and_cache(block, slot, false) {
            Ok(e) => Ok(Serve::Dense(e)),
            Err(e) if self.store.is_some() => self.degrade(block, slot, None, e),
            Err(e) => Err(e),
        }
    }

    /// Barycenter-degraded fallback: answer an unfetchable residual slot
    /// with the shared center alone. Returns the original error when the
    /// center is unavailable too (nothing principled left to serve).
    fn degrade(
        &self,
        block: usize,
        slot: usize,
        center: Option<Arc<ExpertWeights>>,
        err: anyhow::Error,
    ) -> Result<Serve> {
        let center = match center.or_else(|| self.fused_center(block)) {
            Some(c) => c,
            None => return Err(err),
        };
        self.counters.degraded_serves.inc();
        let mut sp = trace::span("cache.degraded");
        sp.key(block, slot);
        Ok(Serve::Degraded(center))
    }

    /// Serve one layer's whole batch window. `wants` is the per-(request,
    /// slot) serve sequence **in serial order** — requests in admission
    /// order, each request's activated slots ascending, each entry carrying
    /// that request's own sub-batch row count — and the result is one
    /// serve result per entry, in the same order the serial loop
    /// `wants.iter().map(|&(s, t)| self.try_serve(block, s, t))` would
    /// answer them. Results are per-want so a failed fetch is pinned on
    /// the one request that owns the want — never on the whole window.
    ///
    /// Parity contract (relaxed since PR 10): decisions match the serial
    /// loop EXCEPT for the [`RESTORE_AMORTIZE_TOKENS`] rule, which sees
    /// the combined window's token total rather than each want's own rows
    /// — a restore is paid once per window, so the whole window amortizes
    /// it. Functional outputs stay exact per serve (Dense/Fused/Paged all
    /// compute the same FFN); what shifts is WHICH arm answers, so the
    /// harness (`prop_decode`) pins conservation laws — every miss is
    /// answered by exactly one of fused/restore/degraded, materializations
    /// are bounded by distinct keys — instead of decision equality.
    ///
    /// The batching win: a warm window (every wanted slot dense-resident)
    /// is answered in ONE metadata critical section — one decide/reserve
    /// per layer per batch instead of per request. Cold and mixed windows
    /// fall back to the serial replay (with the window-total amortization
    /// basis), where the first entry's publish turns the rest of its key's
    /// entries into hits, so every expert is still materialized at most
    /// once per window ([`CacheMetrics::restores_executed`] / shard fetch
    /// counters bound it).
    pub fn try_serve_batch(
        &self,
        block: usize,
        wants: &[(usize, usize)],
    ) -> Vec<Result<Serve>> {
        if wants.is_empty() {
            return Vec::new();
        }
        {
            let mut st = self.lock_state();
            self.counters.batch_windows.inc();
            let bs = st.block_mut(block);
            if wants.iter().all(|(slot, _)| bs.entries.contains_key(slot)) {
                // Warm fast path: replay each want's serial bookkeeping
                // (clock tick, heat bump + decay, hit count, LRU touch)
                // without dropping the lock. No eviction can run here —
                // hits never allocate — so residency checked once holds
                // for the whole window.
                let mut out = Vec::with_capacity(wants.len());
                for &(slot, _) in wants {
                    bs.clock += 1;
                    bs.bump_heat(slot);
                    let e = bs.hit(slot, &self.counters).expect("checked resident");
                    out.push(Ok(Serve::Dense(e)));
                }
                self.counters.batch_warm_windows.inc();
                return out;
            }
        }
        // Cold/mixed window: serial replay with the amortization basis
        // lifted to the window total — the window pays for a restore once,
        // so every row in it counts toward amortizing that restore.
        // Materializations still collapse across the window through
        // residency (first restore publishes, later wants of the key hit)
        // and across concurrent windows through the per-key singleflight.
        // Degradation and per-want errors fall out of the replay
        // automatically, matching serial attribution.
        let window_tokens: usize = wants.iter().map(|&(_, t)| t).sum();
        wants
            .iter()
            .map(|&(slot, _)| self.try_serve_amortized(block, slot, window_tokens))
            .collect()
    }

    /// Reserve a flight for `key` or join the one already in the air.
    /// Callers must have done their own resident-state fast path first.
    fn join_or_lead<'a>(
        &'a self,
        st: &mut CacheState,
        key: FlightKey,
    ) -> std::result::Result<FlightLease<'a>, Arc<Flight>> {
        if let Some(f) = st.flights.get(&key) {
            self.counters.singleflight_waits.inc();
            self.counters.dedup_fetches.inc();
            Err(f.clone())
        } else {
            let f = Arc::new(Flight::new());
            st.flights.insert(key, f.clone());
            Ok(FlightLease { cache: self, key, flight: f, armed: true })
        }
    }

    /// Restore `(block, slot)` to dense weights and cache the result —
    /// decide/reserve, then restore OUTSIDE the lock (singleflight per
    /// key), then publish with a re-check on reacquire.
    fn restore_and_cache(
        &self,
        block: usize,
        slot: usize,
        from_prefetch: bool,
    ) -> Result<Arc<ExpertWeights>> {
        // --- decide/reserve (locked).
        let lease = {
            let mut st = self.lock_state();
            let bs = st.block_mut(block);
            if let Some(expert) = bs.touch_dense_entry(slot, !from_prefetch, &self.counters) {
                // A racing serve published this key between our miss
                // bookkeeping and the reservation (never single-threaded).
                self.counters.dedup_fetches.inc();
                return Ok(expert);
            }
            match self.join_or_lead(&mut st, FlightKey::Dense(block, slot)) {
                Ok(lease) => lease,
                Err(flight) => {
                    drop(st);
                    let waited = {
                        let mut sp = trace::span("flight.wait");
                        sp.key(block, slot);
                        flight.wait()
                    };
                    return match waited {
                        Ok(FlightPayload::Dense(e)) => {
                            self.touch_dense(block, slot, !from_prefetch);
                            Ok(e)
                        }
                        Ok(_) => unreachable!("dense flight yields dense weights"),
                        Err(msg) => Err(anyhow::anyhow!("deduped restore failed: {msg}")),
                    };
                }
            }
        };
        // --- materialize (unlocked): shard fetch (store mode, its own
        // singleflight) + the restore matmuls.
        let layer = self.layers.get(&block).expect("block not compressed");
        let tier = if self.slot_is_quantized(block, slot) { "q8" } else { "f32" };
        let (restored, restore_ns) = if self.store.is_some() {
            // Err, not panic: a CRC-valid artifact whose expert map is
            // shorter than the backbone router's slot count must fail this
            // request, not poison the cache state for every later one.
            let eidx = self.expert_index(block, slot).ok_or_else(|| {
                anyhow::anyhow!("artifact expert map has no entry for block {block} slot {slot}")
            })?;
            let compressed = self.shard_expert(block, eidx, from_prefetch)?;
            assert_unlocked("residual restore matmuls");
            let mut sp = trace::span("cache.restore");
            sp.key(block, slot);
            sp.tier(tier);
            let t0 = Instant::now();
            let restored = Arc::new(layer.restore_expert_from(&compressed));
            (restored, t0.elapsed().as_nanos() as u64)
        } else {
            assert_unlocked("residual restore matmuls");
            let mut sp = trace::span("cache.restore");
            sp.key(block, slot);
            sp.tier(tier);
            let t0 = Instant::now();
            let restored = Arc::new(layer.restore_expert(slot));
            (restored, t0.elapsed().as_nanos() as u64)
        };
        // --- publish (locked): re-check, evict, insert.
        let bytes = expert_bytes(&restored);
        let mut st = self.lock_state();
        self.counters.restore_ns.add(restore_ns);
        self.counters.restores_executed.inc();
        let bs = st.block_mut(block);
        if let Some(resident) = bs.touch_dense_entry(slot, !from_prefetch, &self.counters) {
            // Lost the publish race (possible only against insert paths
            // outside this key's flight); serve the resident copy.
            self.counters.publish_races_lost.inc();
            lease.complete(&mut st, Ok(FlightPayload::Dense(resident.clone())));
            return Ok(resident);
        }
        bs.evict_dense_until_fits(bytes, &self.counters);
        bs.used_bytes += bytes;
        let clock = bs.clock;
        bs.entries.insert(
            slot,
            Entry { expert: restored.clone(), bytes, last_used: clock, from_prefetch },
        );
        bs.trim_shards(&self.counters);
        lease.complete(&mut st, Ok(FlightPayload::Dense(restored.clone())));
        Ok(restored)
    }

    /// Paged compressed expert for `(block, expert index)` — fetch + CRC +
    /// zstd-decode from the backing store OUTSIDE the metadata lock on
    /// first touch (singleflight per key), LRU thereafter.
    fn shard_expert(
        &self,
        block: usize,
        eidx: usize,
        from_prefetch: bool,
    ) -> Result<Arc<CompressedExpert>> {
        // --- decide/reserve (locked).
        let lease = {
            let mut st = self.lock_state();
            let bs = st.block_mut(block);
            if let Some(expert) = bs.touch_shard_entry(eidx, !from_prefetch, &self.counters) {
                return Ok(expert);
            }
            // Quarantined shard with a live TTL: refuse without touching the
            // store (or reserving a flight). Past the TTL the serve falls
            // through and becomes the half-open probe — the singleflight
            // ensures exactly one prober while the rest wait on its flight.
            if let Some(until) = bs.health.get(&eidx).and_then(|h| h.quarantined_until) {
                if Instant::now() < until {
                    return Err(anyhow::anyhow!(
                        "block {block} expert {eidx}: quarantined after repeated fetch failures"
                    ));
                }
            }
            match self.join_or_lead(&mut st, FlightKey::Shard(block, eidx)) {
                Ok(lease) => lease,
                Err(flight) => {
                    drop(st);
                    let waited = {
                        let mut sp = trace::span("flight.wait");
                        sp.key(block, eidx);
                        flight.wait()
                    };
                    return match waited {
                        Ok(FlightPayload::Shard(e)) => {
                            self.touch_shard(block, eidx, !from_prefetch);
                            Ok(e)
                        }
                        Ok(_) => unreachable!("shard flight yields a shard"),
                        Err(msg) => Err(anyhow::anyhow!("deduped shard fetch failed: {msg}")),
                    };
                }
            }
        };
        // --- materialize (unlocked): file read + CRC-32 + zstd decode.
        // Transient failures (retryable I/O) get a bounded, exponentially
        // backed-off retry INSIDE the flight, so every waiter shares the
        // eventually-successful result; integrity failures fail fast.
        assert_unlocked("store shard fetch/decode");
        let store = self.store.clone().expect("shard_expert requires store mode");
        let t0 = Instant::now();
        let mut attempt: u32 = 0;
        let fetched = loop {
            let fetched = {
                let mut sp = trace::span("cache.shard_fetch");
                sp.key(block, eidx);
                let fetched = store.load_expert(block, eidx);
                if let Ok(e) = &fetched {
                    sp.tier(if e.is_quantized() { "q8" } else { "f32" });
                }
                fetched
            };
            match fetched {
                Ok(e) => break Ok(e),
                Err(e) => {
                    if classify_error(&format!("{e:#}")) == ErrorClass::Transient {
                        self.counters.transient_errors.inc();
                        if attempt < FETCH_RETRY_LIMIT {
                            self.counters.fetch_retries.inc();
                            let mut sp = trace::span("cache.retry");
                            sp.key(block, eidx);
                            std::thread::sleep(FETCH_BACKOFF * (1u32 << attempt));
                            attempt += 1;
                            continue;
                        }
                    }
                    break Err(e);
                }
            }
        };
        let fetch_ns = t0.elapsed().as_nanos() as u64;
        // --- publish (locked).
        let mut st = self.lock_state();
        let expert = match fetched {
            Ok(e) => Arc::new(e),
            Err(e) => {
                // Whole-fetch failure (retry budget included): count it
                // against the shard's health; crossing the threshold opens
                // (or re-opens, with a doubled TTL) a quarantine spell.
                let h = st.block_mut(block).health.entry(eidx).or_insert(ShardHealth {
                    consecutive_failures: 0,
                    quarantined_until: None,
                    spells: 0,
                });
                h.consecutive_failures += 1;
                if h.consecutive_failures >= QUARANTINE_THRESHOLD {
                    let exp = h.spells.min(QUARANTINE_MAX_SPELLS);
                    h.quarantined_until = Some(Instant::now() + QUARANTINE_TTL * (1u32 << exp));
                    h.spells += 1;
                    self.counters.quarantined_shards.inc();
                }
                lease.complete(&mut st, Err(format!("{e:#}")));
                return Err(e);
            }
        };
        let bs = st.block_mut(block);
        // A successful fetch clears the failure streak and any quarantine.
        bs.health.remove(&eidx);
        if let Some(resident) = bs.touch_shard_entry(eidx, !from_prefetch, &self.counters) {
            // An async prefetch published this key while we fetched: keep
            // the resident copy (decodes are bit-identical), drop ours —
            // charging neither the fetch count nor its time, so the
            // count/time/bytes triple in `cache_summary` stays consistent.
            self.counters.publish_races_lost.inc();
            lease.complete(&mut st, Ok(FlightPayload::Shard(resident.clone())));
            return Ok(resident);
        }
        self.counters.shard_fetch_ns.add(fetch_ns);
        self.counters.shard_fetches.inc();
        let bytes = expert.memory_bytes();
        self.counters.shard_bytes.add(bytes as u64);
        if expert.is_quantized() {
            self.counters.quant_shard_fetches.inc();
            self.counters.quant_shard_bytes.add(bytes as u64);
        }
        bs.make_room_for_shard(bytes, &self.counters);
        bs.shard_used_bytes += bytes;
        let clock = bs.clock;
        bs.shards.insert(
            eidx,
            ShardEntry {
                expert: expert.clone(),
                fused: None,
                bytes,
                last_used: clock,
                from_prefetch,
            },
        );
        lease.complete(&mut st, Ok(FlightPayload::Shard(expert.clone())));
        Ok(expert)
    }

    /// The lazily-split fused pieces of a paged expert. The split itself
    /// (real matrices, ~the compressed residual again) runs outside the
    /// lock behind its own flight; the nested shard fetch has its own.
    fn fused_shard_expert(&self, block: usize, slot: usize) -> Result<Arc<FusedExpert>> {
        let eidx = self.expert_index(block, slot).ok_or_else(|| {
            anyhow::anyhow!("artifact expert map has no entry for block {block} slot {slot}")
        })?;
        // --- decide/reserve (locked).
        let lease = {
            let mut st = self.lock_state();
            let bs = st.block_mut(block);
            if let Some(fused) = bs.touch_fused_shard(eidx, &self.counters) {
                return Ok(fused);
            }
            match self.join_or_lead(&mut st, FlightKey::FusedShard(block, eidx)) {
                Ok(lease) => lease,
                Err(flight) => {
                    drop(st);
                    let waited = {
                        let mut sp = trace::span("flight.wait");
                        sp.key(block, eidx);
                        flight.wait()
                    };
                    return match waited {
                        Ok(FlightPayload::FusedShard(f)) => {
                            self.touch_shard(block, eidx, true);
                            Ok(f)
                        }
                        Ok(_) => unreachable!("fused-shard flight yields fused pieces"),
                        Err(msg) => Err(anyhow::anyhow!("deduped fused split failed: {msg}")),
                    };
                }
            }
        };
        // --- materialize (unlocked): page the shard in, then split it.
        let compressed = self.shard_expert(block, eidx, false)?;
        let layer = self.layers.get(&block).expect("block not compressed");
        assert_unlocked("fused piece split");
        let fused = {
            let mut sp = trace::span("cache.fused_split");
            sp.key(block, eidx);
            sp.tier(if compressed.is_quantized() { "q8" } else { "f32" });
            Arc::new(compressed.fused(layer.arch, layer.d_model))
        };
        let extra = fused.memory_bytes();
        // --- publish (locked): charge the split pieces to the shard entry
        // so paged_bytes reports the truth and eviction releases the full
        // footprint.
        let mut st = self.lock_state();
        let bs = st.block_mut(block);
        bs.publish_fused_split(eidx, &fused, extra, &self.counters);
        lease.complete(&mut st, Ok(FlightPayload::FusedShard(fused.clone())));
        Ok(fused)
    }

    /// Monolithic mode: the lazily-built fused layer (`None` when the
    /// layer has no shared center). Built outside the lock, once.
    fn fused_layer(&self, block: usize) -> Option<Arc<FusedLayer>> {
        let lease = {
            let mut st = self.lock_state();
            if let Some(f) = &st.blocks.get(&block).expect("block not compressed").fused {
                return f.clone();
            }
            match self.join_or_lead(&mut st, FlightKey::FusedLayer(block)) {
                Ok(lease) => lease,
                Err(flight) => {
                    drop(st);
                    let waited = {
                        let mut sp = trace::span("flight.wait");
                        sp.block(block);
                        flight.wait()
                    };
                    return match waited {
                        Ok(FlightPayload::FusedLayer(f)) => f,
                        // Aborted build: fall back to the restore path.
                        _ => None,
                    };
                }
            }
        };
        assert_unlocked("fused layer densify");
        let built = {
            let mut sp = trace::span("cache.fused_build");
            sp.block(block);
            self.layers
                .get(&block)
                .expect("block not compressed")
                .fused()
                .map(Arc::new)
        };
        let mut st = self.lock_state();
        st.block_mut(block).fused = Some(built.clone());
        lease.complete(&mut st, Ok(FlightPayload::FusedLayer(built.clone())));
        built
    }

    /// Store mode: the densified center expert of `block` (`None` when the
    /// layer has no shared center). Built outside the lock, once.
    fn fused_center(&self, block: usize) -> Option<Arc<ExpertWeights>> {
        let lease = {
            let mut st = self.lock_state();
            if let Some(c) = &st.blocks.get(&block).expect("block not compressed").fused_center
            {
                return c.clone();
            }
            match self.join_or_lead(&mut st, FlightKey::Center(block)) {
                Ok(lease) => lease,
                Err(flight) => {
                    drop(st);
                    let waited = {
                        let mut sp = trace::span("flight.wait");
                        sp.block(block);
                        flight.wait()
                    };
                    return match waited {
                        Ok(FlightPayload::Center(c)) => c,
                        _ => None,
                    };
                }
            }
        };
        assert_unlocked("center densify");
        let built = {
            let mut sp = trace::span("cache.center");
            sp.block(block);
            self.layers
                .get(&block)
                .expect("block not compressed")
                .fused_center()
                .map(Arc::new)
        };
        let mut st = self.lock_state();
        st.block_mut(block).fused_center = Some(built.clone());
        lease.complete(&mut st, Ok(FlightPayload::Center(built.clone())));
        built
    }

    /// The restore-vs-fused cost model (EXPERIMENTS.md §Perf). Restoring
    /// materializes `pI × D` floats once and makes every later hit free;
    /// fused forwards pay O(nnz)/O(rank) extra per call but never touch the
    /// budget. Restore therefore wins iff the dense expert is likely to be
    /// resident when the next request for it arrives — or the current
    /// sub-batch alone amortizes the materialization. All byte accounting
    /// is against this block's own budget share.
    fn should_restore(
        &self,
        bs: &BlockState,
        block: usize,
        slot: usize,
        batch_tokens: usize,
    ) -> bool {
        // 1. A large enough sub-batch amortizes the restore immediately.
        if batch_tokens >= RESTORE_AMORTIZE_TOKENS {
            return true;
        }
        let bytes = self.restored_bytes(block, slot);
        let fits = bs.used_bytes + bytes <= bs.budget_bytes;
        // Int8 residency tier: the paged shard is far smaller than the full
        // f32 expert a restore would materialize, so for quantized
        // residuals mere room is NOT a reason to pay the materialization —
        // they earn a restore only with shown reuse (rule 4), even when
        // they fit. Exact-f32 decisions below are untouched.
        if self.slot_is_quantized(block, slot) {
            return fits && bs.heat.get(&slot).copied().unwrap_or(0) >= HOT_ACCESSES;
        }
        // 2. Fits without evicting anyone → it will stick; restore.
        if fits {
            return true;
        }
        // 3. Larger than the whole share → guaranteed thrash; stay fused.
        if bytes > bs.budget_bytes {
            return false;
        }
        // 4. Tight budget: evict colder residents only for keys with shown
        //    reuse — a cold expert would displace a hotter one just to be
        //    displaced right back.
        bs.heat.get(&slot).copied().unwrap_or(0) >= HOT_ACCESSES
    }

    /// Whether `(block, slot)` is backed by an int8-quantized residual —
    /// answered from the artifact index in store mode (`q8-*` shard kinds,
    /// no shard fetch) and from the resident representation in monolithic
    /// mode. Reads only construction-time-immutable state, so it is safe
    /// both under and outside the metadata lock.
    fn slot_is_quantized(&self, block: usize, slot: usize) -> bool {
        if let Some(store) = &self.store {
            return self.expert_index(block, slot).is_some_and(|eidx| {
                store
                    .layer_entry(block)
                    .and_then(|e| e.experts.get(eidx))
                    .is_some_and(|e| e.kind.starts_with("q8-"))
            });
        }
        let layer = self.layers.get(&block).expect("block not compressed");
        layer
            .expert_map
            .get(slot)
            .and_then(|&e| layer.experts.get(e))
            .is_some_and(|e| e.is_quantized())
    }

    /// Bytes a restored dense expert for `(block, slot)` would occupy
    /// (pI·D design params + b2), computed without restoring — in store
    /// mode from the artifact index, so no shard fetch is needed.
    fn restored_bytes(&self, block: usize, slot: usize) -> usize {
        let layer = self.layers.get(&block).expect("block not compressed");
        if let Some(store) = &self.store {
            let entry = store.layer_entry(block).expect("stored layer");
            return (entry.design_rows * entry.design_cols + layer.d_model) * 4;
        }
        let e = &layer.experts[layer.expert_map[slot]];
        let (pi, d) = e.residual.design_shape();
        (pi * d + e.b2.len()) * 4
    }

    /// Refresh a dense entry's LRU stamp after receiving it through a
    /// flight; `demand` marks prefetched entries useful.
    fn touch_dense(&self, block: usize, slot: usize, demand: bool) {
        let mut st = self.lock_state();
        let _ = st.block_mut(block).touch_dense_entry(slot, demand, &self.counters);
    }

    /// Shard-pool analog of [`ExpertCache::touch_dense`].
    fn touch_shard(&self, block: usize, eidx: usize, demand: bool) {
        let mut st = self.lock_state();
        let _ = st.block_mut(block).touch_shard_entry(eidx, demand, &self.counters);
    }

    /// Pre-warm the cache for the given (block, slot) pairs (the scheduler
    /// calls this with router predictions). Synchronous: monolithic mode
    /// restores dense experts, store mode pages the residual shards in —
    /// both through the same unlocked materialize path as demand serves.
    /// Effectiveness lands in [`CacheMetrics::prefetch_hits`] /
    /// [`CacheMetrics::prefetch_misses`] / [`CacheMetrics::prefetch_useful`]
    /// — demand hit/miss counters are NOT touched, so the serving hit rate
    /// stays attributable to the request stream.
    pub fn prefetch(&self, keys: &[Key]) {
        for &(b, s) in keys {
            if !self.has_layer(b) {
                continue;
            }
            let eidx = self.expert_index(b, s);
            let resident = {
                let mut st = self.lock_state();
                let bs = st.block_mut(b);
                bs.clock += 1;
                let resident = bs.entries.contains_key(&s)
                    || eidx.is_some_and(|eidx| bs.shards.contains_key(&eidx));
                if resident {
                    self.counters.prefetch_hits.inc();
                    bs.touch_key(s, eidx);
                } else {
                    self.counters.prefetch_misses.inc();
                }
                resident
            };
            if resident {
                continue;
            }
            if self.store.is_some() {
                let Some(eidx) = eidx else { continue };
                if self.shard_expert(b, eidx, true).is_err() {
                    // Advisory path: a failed pre-warm never retries,
                    // never quarantines harder than the demand path
                    // already did, and never fails anything upstream.
                    self.note_prefetch_error();
                }
            } else {
                // Monolithic restore cannot fail; errors are impossible but
                // must not panic a pre-warm path either way.
                let _ = self.restore_and_cache(b, s, true);
            }
        }
    }

    /// Plan an async prefetch: record hit/miss metrics for `keys`
    /// ((block, slot) pairs) and return the deduplicated
    /// (block, expert-index) pairs that actually need a fetch. Keys whose
    /// shard is resident, already being fetched by the prefetcher
    /// (`in_flight`, keyed by (block, expert index)), or already being
    /// demand-fetched by a serve (a live `Shard` flight) count as prefetch
    /// hits — the original miss was recorded when the fetch was scheduled,
    /// so usefulness stays an honest per-load ratio. The
    /// [`crate::store::Prefetcher`] decodes the returned keys off-thread
    /// and hands results back through [`ExpertCache::insert_prefetched`].
    pub fn plan_prefetch(
        &self,
        keys: &[Key],
        in_flight: &std::collections::HashSet<(usize, usize)>,
    ) -> Vec<(usize, usize)> {
        let mut st = self.lock_state();
        let mut out = Vec::new();
        for &(b, s) in keys {
            if !self.has_layer(b) {
                continue;
            }
            let Some(eidx) = self.expert_index(b, s) else { continue };
            let shard_in_flight = st.flights.contains_key(&FlightKey::Shard(b, eidx));
            let bs = st.block_mut(b);
            // Never schedule a prediction against a quarantined shard: the
            // demand path is refusing it, so a prefetch would just burn a
            // store round-trip to fail the same way.
            let quarantined = bs
                .health
                .get(&eidx)
                .and_then(|h| h.quarantined_until)
                .is_some_and(|until| Instant::now() < until);
            if quarantined {
                continue;
            }
            if bs.entries.contains_key(&s)
                || bs.shards.contains_key(&eidx)
                || in_flight.contains(&(b, eidx))
                || shard_in_flight
                || out.contains(&(b, eidx))
            {
                self.counters.prefetch_hits.inc();
                // Refresh the resident entry's LRU stamp (as sync prefetch
                // does): the prediction says this key is imminently needed,
                // so it must not be the eviction victim of the very fetches
                // this plan schedules.
                bs.clock += 1;
                bs.touch_key(s, Some(eidx));
            } else {
                self.counters.prefetch_misses.inc();
                out.push((b, eidx));
            }
        }
        out
    }

    /// Install a shard decoded by the async prefetcher. Never evicts dense
    /// residents: if the budget is full of demand entries the result is
    /// dropped (recorded in [`CacheMetrics::prefetch_dropped`]) rather than
    /// displacing proven-hot state with a prediction. A concurrent demand
    /// fetch for the same key loses its publish race against this insert
    /// and serves the copy installed here (decodes are bit-identical).
    pub fn insert_prefetched(&self, block: usize, eidx: usize, expert: CompressedExpert) {
        let mut st = self.lock_state();
        if self.store.is_none() || !st.blocks.contains_key(&block) {
            self.counters.prefetch_dropped.inc();
            return;
        }
        let bs = st.block_mut(block);
        if bs.shards.contains_key(&eidx) {
            self.counters.prefetch_dropped.inc();
            return;
        }
        let bytes = expert.memory_bytes();
        // Can it fit at all beside the dense residents? If not, drop the
        // prediction BEFORE touching the shard pool — evicting every
        // demand-proven shard only to discard the result anyway would be
        // pure churn.
        if bs.used_bytes + bytes > bs.budget_bytes {
            self.counters.prefetch_dropped.inc();
            return;
        }
        bs.make_room_for_shard(bytes, &self.counters);
        bs.clock += 1;
        self.counters.shard_fetches.inc();
        self.counters.shard_bytes.add(bytes as u64);
        if expert.is_quantized() {
            self.counters.quant_shard_fetches.inc();
            self.counters.quant_shard_bytes.add(bytes as u64);
        }
        bs.shard_used_bytes += bytes;
        let clock = bs.clock;
        bs.shards.insert(
            eidx,
            ShardEntry {
                expert: Arc::new(expert),
                fused: None,
                bytes,
                last_used: clock,
                from_prefetch: true,
            },
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::quick_compress;
    use crate::compress::{center_shared_act, fused_forward_expert, ResMoE};
    use crate::moe::{ExpertArch, MoeLayer};
    use crate::store::{pack_compressed_model, quantize_layer, ExpertStore};
    use crate::util::Rng;
    use std::sync::Barrier;

    fn compressed(seed: u64) -> (MoeLayer, CompressedLayer) {
        let mut rng = Rng::new(seed);
        let l = MoeLayer::random(ExpertArch::Relu, 8, 16, 4, 2, true, false, &mut rng);
        let cl = quick_compress(&ResMoE::up(), &l, 0.25, seed);
        (l, cl)
    }

    fn one_expert_bytes() -> usize {
        // relu p=8 pi=16 → (16*8 + 16 + 8*16 + 8) * 4
        (16 * 8 + 16 + 8 * 16 + 8) * 4
    }

    #[test]
    fn restores_correct_experts() {
        let (l, cl) = compressed(1);
        let cache = ExpertCache::new(vec![(3, cl.clone())], usize::MAX);
        for slot in 0..4 {
            let e = cache.get(3, slot);
            let direct = cl.restore_expert(slot);
            assert_eq!(*e, direct);
        }
        let _ = l;
        assert_eq!(cache.metrics().misses, 4);
        assert_eq!(cache.metrics().hits, 0);
    }

    #[test]
    fn hits_after_warm() {
        let (_, cl) = compressed(2);
        let cache = ExpertCache::new(vec![(0, cl)], usize::MAX);
        cache.get(0, 1);
        cache.get(0, 1);
        cache.get(0, 1);
        let m = cache.metrics();
        assert_eq!(m.hits, 2);
        assert_eq!(m.misses, 1);
        assert!(m.hit_rate() > 0.6);
    }

    #[test]
    fn budget_forces_eviction_lru_order() {
        let (_, cl) = compressed(3);
        // Budget for exactly two restored experts.
        let cache = ExpertCache::new(vec![(0, cl)], 2 * one_expert_bytes());
        cache.get(0, 0);
        cache.get(0, 1);
        assert_eq!(cache.resident_experts(), 2);
        cache.get(0, 0); // refresh 0 → LRU victim is 1
        cache.get(0, 2); // evicts 1
        assert_eq!(cache.metrics().evictions, 1);
        cache.get(0, 0); // still resident → hit
        assert_eq!(cache.metrics().hits, 2);
        cache.get(0, 1); // miss again (was evicted)
        assert_eq!(cache.metrics().misses, 4);
    }

    #[test]
    fn tiny_budget_still_serves() {
        let (_, cl) = compressed(4);
        let cache = ExpertCache::new(vec![(0, cl)], 1);
        let e = cache.get(0, 3);
        assert!(e.n_params() > 0);
        assert_eq!(cache.resident_experts(), 1); // single over-budget entry allowed
    }

    #[test]
    fn prefetch_warms_and_records_metrics() {
        let (_, cl) = compressed(5);
        let cache = ExpertCache::new(vec![(2, cl)], usize::MAX);
        cache.prefetch(&[(2, 0), (2, 1), (9, 0)]); // block 9 ignored
        assert_eq!(cache.resident_experts(), 2);
        let m = cache.metrics();
        assert_eq!(m.prefetch_misses, 2);
        assert_eq!(m.prefetch_hits, 0);
        // Prefetch must not pollute the demand counters...
        assert_eq!(m.hits, 0);
        assert_eq!(m.misses, 0);
        cache.get(2, 0);
        assert_eq!(cache.metrics().hits, 1);
        // ...and a demanded prefetched entry counts as useful exactly once.
        cache.get(2, 0);
        assert_eq!(cache.metrics().prefetch_useful, 1);
        // Re-prefetching a resident key is a prefetch hit.
        cache.prefetch(&[(2, 1)]);
        let m = cache.metrics();
        assert_eq!(m.prefetch_hits, 1);
        assert!(m.prefetch_usefulness() > 0.0);
    }

    #[test]
    fn serve_restores_when_budget_has_room() {
        let (_, cl) = compressed(7);
        let cache = ExpertCache::new(vec![(0, cl.clone())], usize::MAX);
        let Serve::Dense(e) = cache.serve(0, 1, 4) else {
            panic!("room in budget must restore")
        };
        assert_eq!(*e, cl.restore_expert(1));
        assert_eq!(cache.metrics().restore_serves, 1);
        assert_eq!(cache.resident_experts(), 1);
        // Second serve is a hit, not a new decision.
        let Serve::Dense(_) = cache.serve(0, 1, 4) else { panic!("hit") };
        assert_eq!(cache.metrics().hits, 1);
        assert_eq!(cache.metrics().restore_serves, 1);
    }

    #[test]
    fn serve_goes_fused_under_thrash_budget() {
        // Budget below one restored expert: every miss must take the fused
        // path and never evict/restore.
        let (_, cl) = compressed(8);
        let budget = one_expert_bytes() / 2;
        let cache = ExpertCache::new(vec![(0, cl.clone())], budget);
        let mut rng = Rng::new(1);
        let x = crate::tensor::Matrix::randn(5, 8, 1.0, &mut rng);
        for slot in [0usize, 1, 2, 3, 0, 1] {
            match cache.serve(0, slot, x.rows) {
                Serve::Fused(fl) => {
                    let shared = fl.shared_act(&x);
                    let got = fl.forward_slot(slot, &x, &shared);
                    let want = cl.restore_expert(slot).forward(&x);
                    assert!(got.sq_dist(&want) < 1e-8, "slot {slot}");
                }
                _ => panic!("thrash budget must serve fused"),
            }
        }
        let m = cache.metrics();
        assert_eq!(m.fused_serves, 6);
        assert_eq!(m.restore_serves, 0);
        assert_eq!(m.evictions, 0);
        assert_eq!(cache.used_bytes(), 0);
        // The fused state is accounted: roughly one densified center plus
        // the compressed residual pieces, and it is reported, not budgeted.
        let fb = cache.fused_bytes();
        assert!(fb >= one_expert_bytes(), "fused state includes the dense center: {fb}");
        assert!(fb < 4 * one_expert_bytes(), "fused state must stay near compressed size: {fb}");
    }

    #[test]
    fn serve_restores_hot_keys_on_tight_budget() {
        // Budget for one expert, two slots competing: the repeatedly-hit
        // slot earns a restore after HOT_ACCESSES, the cold one stays fused.
        let (_, cl) = compressed(9);
        let cache = ExpertCache::new(vec![(0, cl)], one_expert_bytes());
        // Fill the single cache slot with expert 3.
        assert!(matches!(cache.serve(0, 3, 1), Serve::Dense(_)));
        // Expert 0 is cold: first misses go fused...
        assert!(matches!(cache.serve(0, 0, 1), Serve::Fused(_)));
        assert!(matches!(cache.serve(0, 0, 1), Serve::Fused(_)));
        // ...until its heat crosses the threshold and it earns the eviction.
        assert!(matches!(cache.serve(0, 0, 1), Serve::Dense(_)));
        let m = cache.metrics();
        assert_eq!(m.evictions, 1);
        assert_eq!(m.fused_serves, 2);
        assert_eq!(m.restore_serves, 2);
    }

    #[test]
    fn serve_big_batches_restore_even_when_thrashing() {
        let (_, cl) = compressed(10);
        let cache = ExpertCache::new(vec![(0, cl)], 1);
        assert!(matches!(cache.serve(0, 2, 4096), Serve::Dense(_)));
        assert_eq!(cache.metrics().restore_serves, 1);
    }

    #[test]
    fn serve_with_fused_disabled_always_restores() {
        let (_, cl) = compressed(11);
        let cache = ExpertCache::new(vec![(0, cl)], 1);
        cache.set_fused_enabled(false);
        for slot in 0..4 {
            assert!(matches!(cache.serve(0, slot, 1), Serve::Dense(_)));
        }
        let m = cache.metrics();
        assert_eq!(m.restore_serves, 4);
        assert_eq!(m.fused_serves, 0);
    }

    #[test]
    fn compressed_bytes_below_restored() {
        let (l, cl) = compressed(6);
        let cache = ExpertCache::new(vec![(0, cl)], usize::MAX);
        assert!(cache.compressed_bytes() < l.expert_params() * 4);
    }

    #[test]
    fn per_block_budget_partitions_are_independent() {
        // Two compressed blocks share a 2-expert budget → one-expert share
        // each. Filling block 0's share must not stop block 1 from
        // restoring into ITS share (a global pool would have let block 0
        // consume both slots), and block 0's second expert must fall back
        // to the fused path (its own share is full) even though a global
        // pool would still have had room.
        let (_, cl0) = compressed(13);
        let (_, cl1) = compressed(14);
        let cache = ExpertCache::new(vec![(0, cl0), (1, cl1)], 2 * one_expert_bytes());
        assert!(matches!(cache.serve(0, 0, 1), Serve::Dense(_)));
        assert!(matches!(cache.serve(1, 0, 1), Serve::Dense(_)), "block 1 has its own share");
        assert_eq!(cache.resident_experts(), 2);
        // Block 0's share is now full and slot 1 is cold → fused, no
        // eviction (under a global 2-expert pool this would have restored).
        assert!(matches!(cache.serve(0, 1, 1), Serve::Fused(_)));
        let m = cache.metrics();
        assert_eq!(m.evictions, 0);
        assert_eq!(m.fused_serves, 1);
        assert_eq!(m.restore_serves, 2);
    }

    #[test]
    fn serve_batch_warm_window_matches_serial_loop_in_one_lock() {
        // A warm window (every want dense-resident) must be answered in one
        // critical section with metrics bit-identical to the serve loop.
        let (_, cl) = compressed(15);
        let wants: Vec<(usize, usize)> = vec![(1, 3), (2, 2), (1, 4), (2, 1)];
        // Reference: plain serial loop on an identically warmed cache.
        let reference = ExpertCache::new(vec![(0, cl.clone())], usize::MAX);
        reference.serve(0, 1, 1);
        reference.serve(0, 2, 1);
        for &(slot, t) in &wants {
            assert!(matches!(reference.serve(0, slot, t), Serve::Dense(_)));
        }
        let batched = ExpertCache::new(vec![(0, cl.clone())], usize::MAX);
        batched.serve(0, 1, 1);
        batched.serve(0, 2, 1);
        let serves: Vec<Serve> =
            batched.try_serve_batch(0, &wants).into_iter().map(|r| r.unwrap()).collect();
        assert_eq!(serves.len(), wants.len());
        for (s, &(slot, _)) in serves.iter().zip(&wants) {
            match s {
                Serve::Dense(e) => assert_eq!(**e, cl.restore_expert(slot)),
                _ => panic!("warm window serves dense"),
            }
        }
        let (mr, mb) = (reference.metrics(), batched.metrics());
        assert_eq!(mr.hits, mb.hits);
        assert_eq!(mr.misses, mb.misses);
        assert_eq!(mr.restore_serves, mb.restore_serves);
        assert_eq!(mr.fused_serves, mb.fused_serves);
        assert_eq!(mb.batch_windows, 1);
        assert_eq!(mb.batch_warm_windows, 1, "resident window takes the one-lock path");
    }

    #[test]
    fn serve_batch_cold_window_replays_serial_and_materializes_once() {
        // Cold window over two slots with several requests each: decisions
        // and metrics equal the serial loop, and each expert restores once.
        let (_, cl) = compressed(16);
        let wants: Vec<(usize, usize)> = vec![(0, 2), (3, 1), (0, 5), (3, 2), (0, 1)];
        let reference = ExpertCache::new(vec![(0, cl.clone())], usize::MAX);
        let want_serves: Vec<Serve> =
            wants.iter().map(|&(s, t)| reference.serve(0, s, t)).collect();
        let batched = ExpertCache::new(vec![(0, cl)], usize::MAX);
        let got_serves: Vec<Serve> =
            batched.try_serve_batch(0, &wants).into_iter().map(|r| r.unwrap()).collect();
        for (got, want) in got_serves.iter().zip(&want_serves) {
            match (got, want) {
                (Serve::Dense(a), Serve::Dense(b)) => assert_eq!(**a, **b),
                _ => panic!("roomy cold window restores"),
            }
        }
        let (mr, mb) = (reference.metrics(), batched.metrics());
        assert_eq!(mr.hits, mb.hits);
        assert_eq!(mr.misses, mb.misses);
        assert_eq!(mr.restore_serves, mb.restore_serves);
        assert_eq!(mr.restores_executed, mb.restores_executed);
        // The window guarantee: two distinct experts → two restores, not
        // one per want.
        assert_eq!(mb.restores_executed, 2);
        assert_eq!(mb.batch_windows, 1);
        assert_eq!(mb.batch_warm_windows, 0);
    }

    #[test]
    fn concurrent_monolithic_misses_share_one_restore() {
        // N threads cold-missing the same key: one leads the restore, the
        // rest wait on the flight or hit the just-published entry — and
        // every thread holds the SAME Arc, so outputs are bit-identical by
        // construction.
        let (_, cl) = compressed(12);
        let cache = Arc::new(ExpertCache::new(vec![(0, cl.clone())], usize::MAX));
        let n = 8;
        let barrier = Barrier::new(n);
        let got: Vec<Arc<ExpertWeights>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..n)
                .map(|_| {
                    let cache = &cache;
                    let barrier = &barrier;
                    s.spawn(move || {
                        barrier.wait();
                        match cache.serve(0, 2, 1) {
                            Serve::Dense(e) => e,
                            _ => panic!("roomy budget must restore"),
                        }
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for e in &got {
            assert!(Arc::ptr_eq(e, &got[0]), "all threads share one restored expert");
            assert_eq!(**e, cl.restore_expert(2));
        }
        let m = cache.metrics();
        assert_eq!(m.hits + m.misses, n as u64);
        // Exactly one restore ran; every other miss was deduplicated.
        assert_eq!(m.dedup_fetches, m.misses - 1, "{m:?}");
        assert_eq!(m.restore_serves, m.misses, "each miss records its decision");
        assert_eq!(m.restores_executed, 1, "one restore matmul executed: {m:?}");
    }

    // ------------------------------------------------ backing-store mode

    fn store_cache(seed: u64, budget: usize) -> (CompressedLayer, ExpertCache) {
        let mut rng = Rng::new(seed);
        let mut cfg = crate::moe::ModelConfig::switch_mini(4);
        cfg.d_model = 8;
        cfg.d_inner = 16;
        cfg.n_layers = 2;
        cfg.n_heads = 2;
        cfg.vocab_size = 32;
        cfg.max_seq = 16;
        let model = crate::moe::Model::random(&cfg, &mut rng);
        let l = MoeLayer::random(ExpertArch::Relu, 8, 16, 4, 2, true, false, &mut rng);
        let cl = quick_compress(&ResMoE::up(), &l, 0.25, seed);
        let dir = std::env::temp_dir().join("resmoe-cache-store-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("cache-{seed}.rmes"));
        pack_compressed_model(&model, &[(1, cl.clone())], 0.25, &path).unwrap();
        let store = Arc::new(ExpertStore::open(&path).unwrap());
        let cache = ExpertCache::from_store(store, budget).unwrap();
        (cl, cache)
    }

    #[test]
    fn store_mode_pages_only_demanded_shards() {
        let (cl, cache) = store_cache(30, usize::MAX);
        // Skeleton resident, no experts paged yet.
        assert_eq!(cache.resident_shards(), 0);
        assert!(cache.compressed_bytes() > 0);
        let e = cache.get(1, 2);
        assert_eq!(*e, cl.restore_expert(2));
        assert_eq!(cache.metrics().shard_fetches, 1);
        assert_eq!(cache.resident_shards(), 1);
        // Same expert again: dense hit, no second fetch.
        cache.get(1, 2);
        assert_eq!(cache.metrics().shard_fetches, 1);
        assert_eq!(cache.metrics().hits, 1);
        // Different slot mapping to a different expert fetches its shard.
        cache.get(1, 0);
        assert_eq!(cache.metrics().shard_fetches, 2);
    }

    #[test]
    fn store_mode_paged_serve_matches_restore() {
        let (cl, cache) = store_cache(31, 0);
        let mut rng = Rng::new(2);
        let x = crate::tensor::Matrix::randn(5, 8, 1.0, &mut rng);
        for slot in [0usize, 1, 2, 3, 1, 0] {
            match cache.serve(1, slot, x.rows) {
                Serve::Paged { center, expert } => {
                    let sh = center_shared_act(&center, &x);
                    let got = fused_forward_expert(&center, &expert, &x, &sh);
                    let want = cl.restore_expert(slot).forward(&x);
                    assert!(got.sq_dist(&want) < 1e-8, "slot {slot}");
                }
                _ => panic!("zero budget in store mode must serve paged"),
            }
        }
        let m = cache.metrics();
        assert_eq!(m.fused_serves, 6);
        assert_eq!(m.restore_serves, 0);
        assert_eq!(cache.used_bytes(), 0);
        // Paged shards were still fetched (and stayed within... budget 0
        // admits a single over-budget shard at a time).
        assert!(m.shard_fetches >= 4);
    }

    #[test]
    fn store_mode_budget_bounds_paged_bytes() {
        // Budget = one restored expert: paged shards must never push total
        // resident bytes past it (beyond the single-entry allowance).
        let (_, cache) = store_cache(32, one_expert_bytes());
        for slot in [0usize, 1, 2, 3, 0, 1, 2, 3] {
            cache.serve(1, slot, 1);
            assert!(
                cache.resident_shards() <= 4,
                "shards never exceed expert count"
            );
        }
        assert!(cache.metrics().shard_evictions > 0, "tight budget must evict shards");
        // A shard alone is far below one dense expert, so several fit, but
        // the pool stays bounded by the budget.
        assert!(cache.paged_bytes() + cache.used_bytes() <= one_expert_bytes() * 2);
    }

    #[test]
    fn store_mode_sync_prefetch_pages_shards() {
        let (_, cache) = store_cache(33, usize::MAX);
        cache.prefetch(&[(1, 0), (1, 3), (1, 0)]);
        assert_eq!(cache.resident_shards(), 2);
        assert_eq!(cache.resident_experts(), 0, "store-mode prefetch pages, not restores");
        let m = cache.metrics();
        assert_eq!(m.prefetch_misses, 2);
        assert_eq!(m.prefetch_hits, 1);
        // Demand serve of a prefetched shard is useful and fetch-free.
        let fetches = m.shard_fetches;
        cache.serve(1, 0, 1);
        let m = cache.metrics();
        assert_eq!(m.shard_fetches, fetches);
        assert_eq!(m.prefetch_useful, 1);
    }

    #[test]
    fn store_mode_plan_and_insert_prefetched() {
        let (cl, cache) = store_cache(34, usize::MAX);
        let none = std::collections::HashSet::new();
        let plan = cache.plan_prefetch(&[(1, 0), (1, 2), (9, 0), (1, 0)], &none);
        assert_eq!(plan.len(), 2, "deduped, unknown block dropped: {plan:?}");
        let m = cache.metrics();
        assert_eq!(m.prefetch_misses, 2, "batch duplicate is a hit, not a miss");
        assert_eq!(m.prefetch_hits, 1);
        // A key already being fetched elsewhere is a hit too.
        let inflight: std::collections::HashSet<_> = [(1usize, 3usize)].into_iter().collect();
        assert!(cache.plan_prefetch(&[(1, 3)], &inflight).is_empty());
        assert_eq!(cache.metrics().prefetch_hits, 2);
        // Simulate the worker: decode off-thread, hand back.
        let store = cache.backing_store().unwrap().clone();
        for (b, eidx) in plan {
            let expert = store.load_expert(b, eidx).unwrap();
            cache.insert_prefetched(b, eidx, expert);
        }
        assert_eq!(cache.resident_shards(), 2);
        // Demand path finds them without new fetches through the cache.
        let before = cache.metrics().hits;
        let e = cache.get(1, 0);
        assert_eq!(*e, cl.restore_expert(0));
        assert_eq!(cache.metrics().hits, before);
        assert!(cache.metrics().prefetch_useful >= 1);
        // Duplicate insert is dropped.
        let dup = store.load_expert(1, 0).unwrap();
        cache.insert_prefetched(1, 0, dup);
        assert_eq!(cache.metrics().prefetch_dropped, 1);
    }

    #[test]
    fn store_mode_insert_prefetched_never_evicts_dense() {
        let (_, cache) = store_cache(35, one_expert_bytes());
        // Fill the budget with a demanded dense expert.
        cache.serve(1, 0, 4096);
        assert_eq!(cache.resident_experts(), 1);
        let store = cache.backing_store().unwrap().clone();
        let expert = store.load_expert(1, 1).unwrap();
        let dropped_before = cache.metrics().prefetch_dropped;
        cache.insert_prefetched(1, 1, expert);
        assert_eq!(cache.resident_experts(), 1, "dense resident untouched");
        assert_eq!(cache.metrics().prefetch_dropped, dropped_before + 1);
    }

    #[test]
    fn concurrent_store_cold_misses_singleflight_one_fetch() {
        // The satellite guarantee: N workers cold-missing the same expert
        // perform exactly ONE store fetch (and one restore), and all serve
        // weights bit-identical to a serial reference.
        let (cl, cache) = store_cache(36, usize::MAX);
        let cache = Arc::new(cache);
        let n = 8;
        let barrier = Barrier::new(n);
        let got: Vec<Arc<ExpertWeights>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..n)
                .map(|_| {
                    let cache = &cache;
                    let barrier = &barrier;
                    s.spawn(move || {
                        barrier.wait();
                        match cache.try_serve(1, 2, 4096).unwrap() {
                            Serve::Dense(e) => e,
                            _ => panic!("batch 4096 must restore"),
                        }
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let want = cl.restore_expert(2);
        for e in &got {
            assert_eq!(**e, want, "bit-identical to the serial restore");
        }
        let m = cache.metrics();
        assert_eq!(m.shard_fetches, 1, "singleflight: one store fetch, {m:?}");
        assert_eq!(m.hits + m.misses, n as u64);
        assert_eq!(m.dedup_fetches, m.misses - 1, "{m:?}");
    }

    /// A sparser, wider layer than [`store_cache`]'s: at rate 0.1 the
    /// compressed shard PLUS its split fused pieces stay well below one
    /// dense expert, so a budget one notch under the dense size keeps the
    /// cost model fused (rule 3) while the paged state survives trims.
    fn sparse_store_cache(seed: u64, budget: usize) -> ExpertCache {
        let mut rng = Rng::new(seed);
        let mut cfg = crate::moe::ModelConfig::switch_mini(4);
        cfg.d_model = 8;
        cfg.d_inner = 32;
        cfg.n_layers = 2;
        cfg.n_heads = 2;
        cfg.vocab_size = 32;
        cfg.max_seq = 16;
        let model = crate::moe::Model::random(&cfg, &mut rng);
        let l = MoeLayer::random(ExpertArch::Relu, 8, 32, 4, 2, true, false, &mut rng);
        let cl = quick_compress(&ResMoE::up(), &l, 0.1, seed);
        let dir = std::env::temp_dir().join("resmoe-cache-store-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("sparse-{seed}.rmes"));
        pack_compressed_model(&model, &[(1, cl)], 0.1, &path).unwrap();
        let store = Arc::new(ExpertStore::open(&path).unwrap());
        ExpertCache::from_store(store, budget).unwrap()
    }

    #[test]
    fn concurrent_paged_fused_serves_share_one_shard_fetch() {
        // Budget one notch below a dense expert (relu p=8 pI=32 → design
        // 32×17, dense (544+8)·4 = 2208 bytes): the cost model stays fused
        // (rule 3) while the ~rate-0.1 compressed shard + split pieces fit
        // the shard pool, so concurrent fused serves of one key share a
        // single fetch, one center densify, and one split.
        let budget = (32 * 17 + 8) * 4 - 4;
        let reference = sparse_store_cache(37, budget);
        let mut rng = Rng::new(3);
        let x = crate::tensor::Matrix::randn(4, 8, 1.0, &mut rng);
        let want = match reference.serve(1, 1, x.rows) {
            Serve::Paged { center, expert } => {
                let sh = center_shared_act(&center, &x);
                fused_forward_expert(&center, &expert, &x, &sh)
            }
            _ => panic!("budget below one expert must serve paged"),
        };
        assert_eq!(reference.metrics().shard_fetches, 1);
        assert_eq!(
            reference.resident_shards(),
            1,
            "shard + fused pieces must survive the trim for this test to bite"
        );
        let cache = Arc::new(sparse_store_cache(37, budget));
        let n = 6;
        let barrier = Barrier::new(n);
        let outs: Vec<crate::tensor::Matrix> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..n)
                .map(|_| {
                    let cache = &cache;
                    let barrier = &barrier;
                    let x = &x;
                    s.spawn(move || {
                        barrier.wait();
                        match cache.try_serve(1, 1, x.rows).unwrap() {
                            Serve::Paged { center, expert } => {
                                let sh = center_shared_act(&center, &x);
                                fused_forward_expert(&center, &expert, &x, &sh)
                            }
                            _ => panic!("must serve paged"),
                        }
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for out in &outs {
            assert_eq!(out.data, want.data, "bit-identical to the serial fused serve");
        }
        let m = cache.metrics();
        assert_eq!(m.shard_fetches, 1, "singleflight: one store fetch, {m:?}");
        assert_eq!(m.fused_serves, n as u64);
    }

    #[test]
    fn store_mode_serve_batch_replays_serial_decisions() {
        // Store mode, tight budget, a window mixing hot and cold slots:
        // try_serve_batch must reproduce the serial loop's decisions,
        // metrics, and paged residency exactly.
        let wants: Vec<(usize, usize)> = vec![(0, 1), (2, 1), (0, 1), (2, 1), (0, 1), (2, 1)];
        let (_, reference) = store_cache(38, one_expert_bytes());
        let want_serves: Vec<Serve> =
            wants.iter().map(|&(s, t)| reference.serve(1, s, t)).collect();
        let (_, batched) = store_cache(38, one_expert_bytes());
        let got_serves: Vec<Serve> =
            batched.try_serve_batch(1, &wants).into_iter().map(|r| r.unwrap()).collect();
        for (i, (got, want)) in got_serves.iter().zip(&want_serves).enumerate() {
            let same_kind = matches!(
                (got, want),
                (Serve::Dense(_), Serve::Dense(_))
                    | (Serve::Fused(_), Serve::Fused(_))
                    | (Serve::Paged { .. }, Serve::Paged { .. })
            );
            assert!(same_kind, "want {i}: decision kind must match serial");
        }
        let (mr, mb) = (reference.metrics(), batched.metrics());
        assert_eq!(mr.hits, mb.hits);
        assert_eq!(mr.misses, mb.misses);
        assert_eq!(mr.restore_serves, mb.restore_serves);
        assert_eq!(mr.fused_serves, mb.fused_serves);
        assert_eq!(mr.evictions, mb.evictions);
        assert_eq!(mr.shard_fetches, mb.shard_fetches);
        assert_eq!(mr.shard_evictions, mb.shard_evictions);
        assert_eq!(reference.resident_shards(), batched.resident_shards());
        assert_eq!(reference.used_bytes(), batched.used_bytes());
    }

    // ---------------------------------------------- int8 residency tier

    /// Two compressed blocks in ONE artifact — block 1 exact f32, block 3
    /// int8-quantized — exercising both tiers side by side.
    fn mixed_store_cache(
        seed: u64,
        budget: usize,
    ) -> (CompressedLayer, CompressedLayer, ExpertCache) {
        let mut rng = Rng::new(seed);
        let mut cfg = crate::moe::ModelConfig::switch_mini(4);
        cfg.d_model = 8;
        cfg.d_inner = 16;
        cfg.n_layers = 4;
        cfg.n_heads = 2;
        cfg.vocab_size = 32;
        cfg.max_seq = 16;
        let model = crate::moe::Model::random(&cfg, &mut rng);
        let l1 = MoeLayer::random(ExpertArch::Relu, 8, 16, 4, 2, true, false, &mut rng);
        let l3 = MoeLayer::random(ExpertArch::Relu, 8, 16, 4, 2, true, false, &mut rng);
        let cl1 = quick_compress(&ResMoE::up(), &l1, 0.25, seed);
        let cl3 = quick_compress(&ResMoE::up(), &l3, 0.25, seed + 1);
        let dir = std::env::temp_dir().join("resmoe-cache-store-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("mixed-{seed}.rmes"));
        pack_compressed_model(
            &model,
            &[(1, cl1.clone()), (3, quantize_layer(&cl3))],
            0.25,
            &path,
        )
        .unwrap();
        let store = Arc::new(ExpertStore::open(&path).unwrap());
        let cache = ExpertCache::from_store(store, budget).unwrap();
        (cl1, cl3, cache)
    }

    #[test]
    fn store_mode_mixed_f32_and_quantized_blocks() {
        let (cl1, cl3, cache) = mixed_store_cache(40, usize::MAX);
        let cl3q = quantize_layer(&cl3);
        // Exact block: a roomy budget restores on first serve, bit-exact,
        // and no quantized counter moves.
        for slot in 0..4 {
            match cache.serve(1, slot, 1) {
                Serve::Dense(e) => assert_eq!(*e, cl1.restore_expert(slot)),
                _ => panic!("roomy f32 slot must restore"),
            }
        }
        let m = cache.metrics();
        assert_eq!(m.restore_serves, 4);
        assert_eq!(m.quant_serves, 0, "f32 serves never count as quantized");
        assert_eq!(m.quant_shard_fetches, 0);
        assert_eq!(m.quant_shard_bytes, 0);
        // Quantized block: cold slots stay paged even though they'd fit.
        let mut rng = Rng::new(7);
        let x = crate::tensor::Matrix::randn(3, 8, 1.0, &mut rng);
        for slot in 0..4 {
            match cache.serve(3, slot, 1) {
                Serve::Paged { center, expert } => {
                    assert!(expert.is_quantized(), "slot {slot}");
                    let sh = center_shared_act(&center, &x);
                    let got = fused_forward_expert(&center, &expert, &x, &sh);
                    // Tight vs the quantized restore (same dequantized
                    // values; fused-vs-restore reassociation only)...
                    let wq = cl3q.restore_expert(slot).forward(&x);
                    assert!(got.sq_dist(&wq) < 1e-8, "slot {slot}");
                    // ...and within quantization-error reach of the
                    // original f32 expert's output.
                    let wf = cl3.restore_expert(slot).forward(&x);
                    let rel = got.sq_dist(&wf) / wf.frob_norm_sq().max(1e-12);
                    assert!(rel < 1e-2, "slot {slot}: rel={rel}");
                }
                _ => panic!("cold quantized slot must stay paged (slot {slot})"),
            }
        }
        let m = cache.metrics();
        assert_eq!(m.fused_serves, 4);
        assert_eq!(m.quant_serves, 4);
        assert_eq!(m.quant_shard_fetches, 4);
        // The int8 block's resident shard bytes undercut its f32 sibling's
        // (same shapes, same rate — only the value storage differs).
        assert!(
            m.quant_shard_bytes > 0 && m.quant_shard_bytes < m.shard_bytes - m.quant_shard_bytes,
            "int8 shard bytes {} vs f32 {}",
            m.quant_shard_bytes,
            m.shard_bytes - m.quant_shard_bytes,
        );
        // Shown reuse flips the decision: the third access of slot 0
        // crosses HOT_ACCESSES and earns the dense restore, bit-exact with
        // restoring from the quantized layer directly.
        assert!(matches!(cache.serve(3, 0, 1), Serve::Paged { .. }));
        match cache.serve(3, 0, 1) {
            Serve::Dense(e) => assert_eq!(*e, cl3q.restore_expert(0)),
            _ => panic!("hot quantized slot must restore"),
        }
        let m = cache.metrics();
        assert_eq!(m.quant_serves, 6);
        assert_eq!(m.restore_serves, 5);
        // An amortizing batch restores immediately regardless of heat.
        match cache.serve(3, 2, RESTORE_AMORTIZE_TOKENS) {
            Serve::Dense(e) => assert_eq!(*e, cl3q.restore_expert(2)),
            _ => panic!("big batch must restore"),
        }
    }

    // ------------------------------------------------- observability (PR 7)

    #[test]
    fn metrics_and_recording_are_lock_free() {
        // THE PR-7 claim, asserted via the PR-3 lock-held machinery: take
        // the metadata lock (non-reentrant — a second lock_state() on this
        // thread debug-panics, a mutex re-lock would deadlock) and, while
        // holding it, snapshot metrics AND record events. If either path
        // touched the metadata mutex this test could not pass.
        let (_, cl) = compressed(50);
        let cache = ExpertCache::new(vec![(0, cl)], usize::MAX);
        cache.serve(0, 1, 1);
        cache.serve(0, 1, 1);
        let guard = cache.lock_state();
        let m = cache.metrics(); // snapshot under the held lock
        assert_eq!(m.hits, 1);
        assert_eq!(m.misses, 1);
        cache.counters.hits.inc(); // record under the held lock
        cache.note_prefetch_dropped();
        drop(guard);
        let m = cache.metrics();
        assert_eq!(m.hits, 2);
        assert_eq!(m.prefetch_dropped, 1);
    }

    #[test]
    fn serve_hammering_with_concurrent_snapshots_never_blocks() {
        // Satellite: 8 threads hammering serves while snapshot threads spin
        // — recording takes no mutex, so totals stay exact and no snapshot
        // can stall a serve. Totals are checked after join (relaxed
        // atomics are exact once quiesced).
        use std::sync::atomic::{AtomicBool, Ordering};
        let (_, cl) = compressed(51);
        let cache = Arc::new(ExpertCache::new(vec![(0, cl)], usize::MAX));
        let n_threads = 8u64;
        let per_thread = 200u64;
        let stop = AtomicBool::new(false);
        std::thread::scope(|s| {
            let servers: Vec<_> = (0..n_threads)
                .map(|t| {
                    let cache = &cache;
                    s.spawn(move || {
                        for i in 0..per_thread {
                            let slot = ((t + i) % 4) as usize;
                            cache.serve(0, slot, 1);
                        }
                    })
                })
                .collect();
            for _ in 0..2 {
                let (cache, stop) = (&cache, &stop);
                s.spawn(move || {
                    while !stop.load(Ordering::Relaxed) {
                        let m = cache.metrics();
                        // Mid-flight cross-sections are monotone per field.
                        assert!(m.hits + m.misses <= n_threads * per_thread);
                    }
                });
            }
            for h in servers {
                h.join().unwrap();
            }
            stop.store(true, Ordering::Relaxed);
        });
        let m = cache.metrics();
        assert_eq!(m.hits + m.misses, n_threads * per_thread);
        assert_eq!(m.restores_executed, 4, "one restore per distinct slot");
    }

    #[test]
    fn tracing_toggle_leaves_decisions_and_metrics_identical() {
        // Observation never feeds back: the same request sequence under
        // tracing off vs on yields identical decisions and counters.
        let _g = trace::test_serial();
        let run = |on: bool| {
            trace::force_for_tests(Some(on));
            let (_, cache) = store_cache(52, one_expert_bytes());
            for &(slot, t) in &[(0usize, 1usize), (2, 1), (0, 1), (2, 1), (0, 600)] {
                let _ = cache.serve(1, slot, t);
            }
            trace::force_for_tests(None);
            cache.metrics()
        };
        let off = run(false);
        let on = run(true);
        trace::drain_test_lines();
        assert_eq!(format!("{off:?}"), format!("{on:?}"));
    }

    #[test]
    fn monolithic_quantized_layer_stays_fused_until_hot() {
        let (_, cl) = compressed(41);
        let clq = quantize_layer(&cl);
        let cache = ExpertCache::new(vec![(0, clq.clone())], usize::MAX);
        // A roomy budget restores an f32 layer on first miss (rule 2); the
        // int8 tier demands shown reuse first.
        assert!(matches!(cache.serve(0, 1, 1), Serve::Fused(_)));
        assert!(matches!(cache.serve(0, 1, 1), Serve::Fused(_)));
        match cache.serve(0, 1, 1) {
            Serve::Dense(e) => assert_eq!(*e, clq.restore_expert(1)),
            _ => panic!("hot quantized slot must restore"),
        }
        let m = cache.metrics();
        assert_eq!(m.fused_serves, 2);
        assert_eq!(m.restore_serves, 1);
        assert_eq!(m.quant_serves, 3);
    }

    #[test]
    fn batch_window_amortizes_restores_over_combined_tokens() {
        // Window-level RESTORE_AMORTIZE_TOKENS (PR 10): three cold
        // quantized wants of 200 tokens each would all serve fused in the
        // serial loop (each below the 512-token amortization bar, heat
        // cold), but the combined window carries 600 tokens, so the
        // batched window restores every one of them.
        let (_, cl) = compressed(60);
        let clq = quantize_layer(&cl);

        // Serial reference: 200 tokens alone stays fused.
        let serial = ExpertCache::new(vec![(0, clq.clone())], usize::MAX);
        assert!(matches!(serial.serve(0, 1, 200), Serve::Fused(_)));

        let cache = ExpertCache::new(vec![(0, clq)], usize::MAX);
        let wants = [(1usize, 200usize), (2, 200), (3, 200)];
        for r in cache.try_serve_batch(0, &wants) {
            assert!(matches!(r.unwrap(), Serve::Dense(_)));
        }
        let m = cache.metrics();
        assert_eq!(m.misses, 3);
        assert_eq!(m.restore_serves, 3);
        assert_eq!(m.fused_serves, 0);
        // Conservation: every miss answered by exactly one serve arm.
        assert_eq!(m.misses, m.restore_serves + m.fused_serves + m.degraded_serves);
    }

    #[test]
    fn kv_pool_shares_budget_without_shrinking_expert_pools() {
        // The KV pool gets one per-block share of the cache budget, in
        // addition to the dense/shard partitions: exhausting it refuses
        // new KV leases but leaves expert residency untouched.
        let (_, cl) = compressed(61);
        let budget = 2 * one_expert_bytes();
        let cache = ExpertCache::new(vec![(0, cl)], budget);
        assert_eq!(cache.kv_pool().max_bytes(), budget);

        let lease = cache.kv_pool().lease(budget).expect("pool-sized lease fits");
        assert!(cache.kv_pool().lease(1).is_none(), "pool is full");
        // Expert serving is oblivious to KV pressure: both dense slots
        // still restore and stay resident under the full lease.
        cache.get(0, 0);
        cache.get(0, 1);
        assert_eq!(cache.resident_experts(), 2);
        assert_eq!(cache.metrics().evictions, 0);

        // Releasing the lease conserves every byte.
        drop(lease);
        assert_eq!(cache.kv_pool().used_bytes(), 0);
        assert_eq!(cache.kv_pool().live_leases(), 0);
        assert_eq!(
            cache.kv_pool().leases_granted(),
            cache.kv_pool().leases_released()
        );
        assert_eq!(cache.kv_pool().refusals(), 1);
        assert!(cache.kv_pool().lease(budget).is_some());
    }
}
