//! Restored-expert LRU cache — the paper's Algorithm 2 ("reconstruct and
//! dynamically load the compressed experts") as a serving-runtime feature.
//!
//! Resident set: the per-layer barycenter `W_ω` lives inside the
//! [`CompressedLayer`] (always in memory, small); restored dense experts
//! are materialized on router demand into an LRU cache bounded by a byte
//! budget. When the budget is smaller than the full restored model, the
//! cache trades restore latency for memory — exactly the knob the paper's
//! space-efficiency argument is about.

use crate::compress::CompressedLayer;
use crate::moe::ExpertWeights;
use std::collections::HashMap;
use std::sync::Arc;

/// (block index, router slot) → restored expert.
type Key = (usize, usize);

#[derive(Debug, Default, Clone)]
pub struct CacheMetrics {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub restore_ns: u64,
}

impl CacheMetrics {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

struct Entry {
    expert: Arc<ExpertWeights>,
    bytes: usize,
    /// LRU stamp (monotone counter).
    last_used: u64,
}

/// LRU cache of restored experts over a set of compressed layers.
pub struct ExpertCache {
    layers: HashMap<usize, CompressedLayer>,
    entries: HashMap<Key, Entry>,
    budget_bytes: usize,
    used_bytes: usize,
    clock: u64,
    pub metrics: CacheMetrics,
}

fn expert_bytes(e: &ExpertWeights) -> usize {
    e.n_params() * 4
}

impl ExpertCache {
    pub fn new(layers: Vec<(usize, CompressedLayer)>, budget_bytes: usize) -> ExpertCache {
        ExpertCache {
            layers: layers.into_iter().collect(),
            entries: HashMap::new(),
            budget_bytes,
            used_bytes: 0,
            clock: 0,
            metrics: CacheMetrics::default(),
        }
    }

    pub fn has_layer(&self, block: usize) -> bool {
        self.layers.contains_key(&block)
    }

    pub fn layer(&self, block: usize) -> Option<&CompressedLayer> {
        self.layers.get(&block)
    }

    /// Bytes of the always-resident compressed representations.
    pub fn compressed_bytes(&self) -> usize {
        self.layers.values().map(|l| l.memory_bytes()).sum()
    }

    pub fn used_bytes(&self) -> usize {
        self.used_bytes
    }

    /// Fetch (restoring if needed) the expert for `(block, slot)`.
    pub fn get(&mut self, block: usize, slot: usize) -> Arc<ExpertWeights> {
        self.clock += 1;
        let clock = self.clock;
        if let Some(e) = self.entries.get_mut(&(block, slot)) {
            e.last_used = clock;
            self.metrics.hits += 1;
            return e.expert.clone();
        }
        self.metrics.misses += 1;
        let t0 = std::time::Instant::now();
        let layer = self.layers.get(&block).expect("block not compressed");
        let restored = Arc::new(layer.restore_expert(slot));
        self.metrics.restore_ns += t0.elapsed().as_nanos() as u64;
        let bytes = expert_bytes(&restored);
        // Evict LRU entries until the new expert fits (a single expert
        // larger than the whole budget is allowed in alone).
        while self.used_bytes + bytes > self.budget_bytes && !self.entries.is_empty() {
            let (&victim, _) = self
                .entries
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .expect("nonempty");
            let removed = self.entries.remove(&victim).unwrap();
            self.used_bytes -= removed.bytes;
            self.metrics.evictions += 1;
        }
        self.used_bytes += bytes;
        self.entries.insert(
            (block, slot),
            Entry { expert: restored.clone(), bytes, last_used: clock },
        );
        restored
    }

    /// Pre-warm the cache for the given (block, slot) pairs (the scheduler
    /// calls this with router predictions).
    pub fn prefetch(&mut self, keys: &[Key]) {
        for &(b, s) in keys {
            if self.has_layer(b) {
                let _ = self.get(b, s);
            }
        }
    }

    pub fn resident_experts(&self) -> usize {
        self.entries.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::quick_compress;
    use crate::compress::ResMoE;
    use crate::moe::{ExpertArch, MoeLayer};
    use crate::util::Rng;

    fn compressed(seed: u64) -> (MoeLayer, CompressedLayer) {
        let mut rng = Rng::new(seed);
        let l = MoeLayer::random(ExpertArch::Relu, 8, 16, 4, 2, true, false, &mut rng);
        let cl = quick_compress(&ResMoE::up(), &l, 0.25, seed);
        (l, cl)
    }

    fn one_expert_bytes() -> usize {
        // relu p=8 pi=16 → (16*8 + 16 + 8*16 + 8) * 4
        (16 * 8 + 16 + 8 * 16 + 8) * 4
    }

    #[test]
    fn restores_correct_experts() {
        let (l, cl) = compressed(1);
        let mut cache = ExpertCache::new(vec![(3, cl.clone())], usize::MAX);
        for slot in 0..4 {
            let e = cache.get(3, slot);
            let direct = cl.restore_expert(slot);
            assert_eq!(*e, direct);
        }
        let _ = l;
        assert_eq!(cache.metrics.misses, 4);
        assert_eq!(cache.metrics.hits, 0);
    }

    #[test]
    fn hits_after_warm() {
        let (_, cl) = compressed(2);
        let mut cache = ExpertCache::new(vec![(0, cl)], usize::MAX);
        cache.get(0, 1);
        cache.get(0, 1);
        cache.get(0, 1);
        assert_eq!(cache.metrics.hits, 2);
        assert_eq!(cache.metrics.misses, 1);
        assert!(cache.metrics.hit_rate() > 0.6);
    }

    #[test]
    fn budget_forces_eviction_lru_order() {
        let (_, cl) = compressed(3);
        // Budget for exactly two restored experts.
        let mut cache = ExpertCache::new(vec![(0, cl)], 2 * one_expert_bytes());
        cache.get(0, 0);
        cache.get(0, 1);
        assert_eq!(cache.resident_experts(), 2);
        cache.get(0, 0); // refresh 0 → LRU victim is 1
        cache.get(0, 2); // evicts 1
        assert_eq!(cache.metrics.evictions, 1);
        cache.get(0, 0); // still resident → hit
        assert_eq!(cache.metrics.hits, 2);
        cache.get(0, 1); // miss again (was evicted)
        assert_eq!(cache.metrics.misses, 4);
    }

    #[test]
    fn tiny_budget_still_serves() {
        let (_, cl) = compressed(4);
        let mut cache = ExpertCache::new(vec![(0, cl)], 1);
        let e = cache.get(0, 3);
        assert!(e.n_params() > 0);
        assert_eq!(cache.resident_experts(), 1); // single over-budget entry allowed
    }

    #[test]
    fn prefetch_warms() {
        let (_, cl) = compressed(5);
        let mut cache = ExpertCache::new(vec![(2, cl)], usize::MAX);
        cache.prefetch(&[(2, 0), (2, 1), (9, 0)]); // block 9 ignored
        assert_eq!(cache.resident_experts(), 2);
        cache.get(2, 0);
        assert_eq!(cache.metrics.hits, 1);
    }

    #[test]
    fn compressed_bytes_below_restored() {
        let (l, cl) = compressed(6);
        let cache = ExpertCache::new(vec![(0, cl)], usize::MAX);
        assert!(cache.compressed_bytes() < l.expert_params() * 4);
    }
}
