//! Restored-expert LRU cache — the paper's Algorithm 2 ("reconstruct and
//! dynamically load the compressed experts") as a serving-runtime feature —
//! plus the **fused-vs-restore cost model** for cache misses and the
//! **backing-store demand-paging mode**.
//!
//! Resident set: the per-layer barycenter `W_ω` lives inside the
//! [`CompressedLayer`] (always in memory, small); restored dense experts
//! are materialized on router demand into an LRU cache bounded by a byte
//! budget. When the budget is smaller than the full restored model, the
//! cache trades restore latency for memory — exactly the knob the paper's
//! space-efficiency argument is about.
//!
//! A miss no longer has to restore: [`ExpertCache::serve`] can answer with
//! the layer's [`FusedLayer`] instead, scoring tokens straight from the
//! compressed representation. The policy (see `should_restore`): restoring
//! pays a dense materialization once and makes every future hit free, so it
//! wins for experts that will stay resident; the fused path wins when the
//! budget cannot hold the expert anyway (thrash) or the expert is cold.
//! Decisions are recorded in [`CacheMetrics`].
//!
//! **Backing-store mode** ([`ExpertCache::from_store`]): instead of holding
//! every compressed residual in memory, the cache keeps only the per-layer
//! skeletons (center + routing metadata) resident and pages individual
//! expert residual shards in from an `RMES` artifact on demand. Paged
//! shards share the byte budget with restored dense experts and are evicted
//! first (they are cheap to refetch); the fused/restore cost model is
//! unchanged and keyed on the dense-resident bytes alone, so a store-backed
//! engine makes byte-identical serving decisions to a monolithic one under
//! the same request stream. Fused misses answer with [`Serve::Paged`] — the
//! densified center plus the one paged expert's split pieces — so no full
//! [`FusedLayer`] (which would need every shard) is ever built.

use crate::compress::{CompressedExpert, CompressedLayer, FusedExpert, FusedLayer};
use crate::moe::ExpertWeights;
use crate::store::ExpertStore;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::sync::Arc;

/// (block index, router slot) → restored expert. Paged shards are keyed by
/// (block index, stored-expert index) — identical unless a merge method
/// made `expert_map` non-injective.
type Key = (usize, usize);

#[derive(Debug, Default, Clone)]
pub struct CacheMetrics {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub restore_ns: u64,
    /// Misses answered by restoring + caching a dense expert.
    pub restore_serves: u64,
    /// Misses answered restore-free through the fused path.
    pub fused_serves: u64,
    /// Prefetch requests that found the key already resident.
    pub prefetch_hits: u64,
    /// Prefetch requests that had to load (or schedule loading of) the key.
    pub prefetch_misses: u64,
    /// Demand accesses served by an entry a prefetch brought in — the
    /// prefetcher's effectiveness numerator.
    pub prefetch_useful: u64,
    /// Async prefetch results discarded (raced a demand fetch, or the
    /// budget was full of demand-resident bytes).
    pub prefetch_dropped: u64,
    /// Residual shards fetched + decoded from the backing store.
    pub shard_fetches: u64,
    pub shard_fetch_ns: u64,
    /// Decoded bytes of fetched shards.
    pub shard_bytes: u64,
    /// Paged shards evicted to make room.
    pub shard_evictions: u64,
}

impl CacheMetrics {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Fraction of prefetched entries that later served a demand access.
    pub fn prefetch_usefulness(&self) -> f64 {
        if self.prefetch_misses == 0 {
            0.0
        } else {
            self.prefetch_useful as f64 / self.prefetch_misses as f64
        }
    }
}

/// How [`ExpertCache::serve`] answers a lookup.
pub enum Serve {
    /// Dense weights: a cache hit, or a miss the policy chose to restore
    /// (and cache).
    Dense(Arc<ExpertWeights>),
    /// Restore-free: forward through [`FusedLayer::forward_slot`].
    Fused(Arc<FusedLayer>),
    /// Restore-free in backing-store mode: the densified center plus the
    /// single paged expert — forward through
    /// [`crate::compress::fused_forward_expert`] with a
    /// [`crate::compress::center_shared_act`] shared term.
    Paged { center: Arc<ExpertWeights>, expert: Arc<FusedExpert> },
}

struct Entry {
    expert: Arc<ExpertWeights>,
    bytes: usize,
    /// LRU stamp (monotone counter).
    last_used: u64,
    /// Brought in by a prefetch and not yet demanded.
    from_prefetch: bool,
}

struct ShardEntry {
    expert: Arc<CompressedExpert>,
    /// Lazily-split fused pieces for the paged serve path.
    fused: Option<Arc<FusedExpert>>,
    bytes: usize,
    last_used: u64,
    from_prefetch: bool,
}

/// LRU cache of restored experts over a set of compressed layers, with an
/// optional backing artifact store for the residual shards.
pub struct ExpertCache {
    layers: HashMap<usize, CompressedLayer>,
    entries: HashMap<Key, Entry>,
    /// Lazily built fused state per block (`None` = layer has no center).
    /// Monolithic mode only — store mode uses `fused_centers` + per-shard
    /// pieces instead.
    fused: HashMap<usize, Option<Arc<FusedLayer>>>,
    /// Backing store (None = monolithic mode: every residual in memory).
    store: Option<Arc<ExpertStore>>,
    /// Store mode: paged residual shards, keyed by (block, expert index).
    shards: HashMap<Key, ShardEntry>,
    shard_used_bytes: usize,
    /// Store mode: densified centers (`None` = layer has no center).
    fused_centers: HashMap<usize, Option<Arc<ExpertWeights>>>,
    /// Decayed per-key access counts driving the restore-vs-fused choice.
    heat: HashMap<Key, u32>,
    /// serve() calls so far — the decay clock for `heat`. Deliberately NOT
    /// the LRU `clock` (which get()/prefetch() also advance): decay must
    /// tick every HEAT_DECAY_PERIOD serves regardless of interleaving.
    serve_accesses: u64,
    /// Master switch for the fused path (benches compare both policies).
    fused_enabled: bool,
    budget_bytes: usize,
    used_bytes: usize,
    clock: u64,
    pub metrics: CacheMetrics,
}

fn expert_bytes(e: &ExpertWeights) -> usize {
    e.n_params() * 4
}

/// Accesses in the decay window after which a key counts as hot enough to
/// evict colder residents for (see `should_restore`).
const HOT_ACCESSES: u32 = 3;
/// Halve every heat counter each time this many accesses elapse, so "hot"
/// tracks the recent request mix rather than all of history.
const HEAT_DECAY_PERIOD: u64 = 256;
/// Sub-batches at least this large amortize a restore within the single
/// call, so restore regardless of heat.
const RESTORE_AMORTIZE_TOKENS: usize = 512;

impl ExpertCache {
    pub fn new(layers: Vec<(usize, CompressedLayer)>, budget_bytes: usize) -> ExpertCache {
        ExpertCache {
            layers: layers.into_iter().collect(),
            entries: HashMap::new(),
            fused: HashMap::new(),
            store: None,
            shards: HashMap::new(),
            shard_used_bytes: 0,
            fused_centers: HashMap::new(),
            heat: HashMap::new(),
            serve_accesses: 0,
            fused_enabled: true,
            budget_bytes,
            used_bytes: 0,
            clock: 0,
            metrics: CacheMetrics::default(),
        }
    }

    /// Backing-store mode: load only the per-layer skeletons (center +
    /// routing metadata) eagerly; every residual shard pages in on demand
    /// through [`ExpertCache::serve`] / [`ExpertCache::prefetch`].
    pub fn from_store(store: Arc<ExpertStore>, budget_bytes: usize) -> Result<ExpertCache> {
        let mut layers = HashMap::new();
        for block in store.blocks() {
            let skeleton = store
                .load_layer_skeleton(block)
                .with_context(|| format!("load skeleton for block {block}"))?;
            layers.insert(block, skeleton);
        }
        let mut cache = ExpertCache::new(Vec::new(), budget_bytes);
        cache.layers = layers;
        cache.store = Some(store);
        Ok(cache)
    }

    /// The backing store, when in store mode.
    pub fn backing_store(&self) -> Option<&Arc<ExpertStore>> {
        self.store.as_ref()
    }

    /// Enable/disable the fused serve path (`true` by default). With it off
    /// every miss restores — the seed's behavior, kept for A/B benching.
    pub fn set_fused_enabled(&mut self, enabled: bool) {
        self.fused_enabled = enabled;
    }

    pub fn has_layer(&self, block: usize) -> bool {
        self.layers.contains_key(&block)
    }

    pub fn layer(&self, block: usize) -> Option<&CompressedLayer> {
        self.layers.get(&block)
    }

    /// Stored-expert index behind router slot `slot` of `block`.
    pub fn expert_index(&self, block: usize, slot: usize) -> Option<usize> {
        self.layers.get(&block)?.expert_map.get(slot).copied()
    }

    /// Whether a demand access for `(block, slot)` would be answered from
    /// memory (dense-restored entry, or paged shard in store mode).
    pub fn is_resident(&self, block: usize, slot: usize) -> bool {
        if self.entries.contains_key(&(block, slot)) {
            return true;
        }
        match self.expert_index(block, slot) {
            Some(eidx) => self.shards.contains_key(&(block, eidx)),
            None => false,
        }
    }

    /// Bytes of the always-resident compressed representations (store mode:
    /// just the skeletons — centers + routing metadata).
    pub fn compressed_bytes(&self) -> usize {
        self.layers.values().map(|l| l.memory_bytes()).sum()
    }

    /// Bytes of the lazily-built fused state (densified center expert +
    /// split residual pieces per block that has served fused). This is
    /// center-sized, per-layer — NOT per-expert — so it is reported here
    /// rather than charged against the LRU budget, which governs the
    /// per-expert restored set; a deployment sizing memory should add
    /// `compressed_bytes + fused_bytes + budget`.
    pub fn fused_bytes(&self) -> usize {
        let monolithic: usize = self
            .fused
            .values()
            .filter_map(|f| f.as_ref())
            .map(|f| f.memory_bytes())
            .sum();
        let centers: usize = self
            .fused_centers
            .values()
            .filter_map(|c| c.as_ref())
            .map(|c| c.n_params() * 4)
            .sum();
        monolithic + centers
    }

    pub fn used_bytes(&self) -> usize {
        self.used_bytes
    }

    /// Bytes of paged residual shards currently resident (store mode).
    pub fn paged_bytes(&self) -> usize {
        self.shard_used_bytes
    }

    /// Fetch (restoring if needed) the expert for `(block, slot)` — the
    /// plain Algorithm-2 path: every miss restores and caches.
    pub fn get(&mut self, block: usize, slot: usize) -> Arc<ExpertWeights> {
        self.clock += 1;
        if let Some(e) = self.hit(block, slot) {
            return e;
        }
        self.metrics.misses += 1;
        self.restore_and_cache(block, slot).expect("expert shard fetch failed")
    }

    /// Serve `(block, slot)` for a sub-batch of `batch_tokens` tokens,
    /// choosing between the cached/restored dense expert and the
    /// restore-free fused path per the cost model. Decisions land in
    /// [`CacheMetrics::restore_serves`] / [`CacheMetrics::fused_serves`].
    ///
    /// Panics in store mode when a shard cannot be fetched or fails its
    /// checksum — a corrupt artifact must never be silently served; use
    /// [`ExpertCache::try_serve`] to handle the error instead.
    pub fn serve(&mut self, block: usize, slot: usize, batch_tokens: usize) -> Serve {
        self.try_serve(block, slot, batch_tokens).expect("expert shard fetch failed")
    }

    /// Fallible [`ExpertCache::serve`] (store fetch / integrity errors).
    pub fn try_serve(&mut self, block: usize, slot: usize, batch_tokens: usize) -> Result<Serve> {
        self.clock += 1;
        self.bump_heat((block, slot));
        if let Some(e) = self.hit(block, slot) {
            return Ok(Serve::Dense(e));
        }
        self.metrics.misses += 1;
        if self.fused_enabled && !self.should_restore(block, slot, batch_tokens) {
            if self.store.is_some() {
                if let Some(center) = self.fused_center(block) {
                    let expert = self.fused_shard_expert(block, slot)?;
                    self.metrics.fused_serves += 1;
                    return Ok(Serve::Paged { center, expert });
                }
            } else if let Some(fl) = self.fused_layer(block) {
                self.metrics.fused_serves += 1;
                return Ok(Serve::Fused(fl));
            }
        }
        self.metrics.restore_serves += 1;
        Ok(Serve::Dense(self.restore_and_cache(block, slot)?))
    }

    fn hit(&mut self, block: usize, slot: usize) -> Option<Arc<ExpertWeights>> {
        let clock = self.clock;
        let e = self.entries.get_mut(&(block, slot))?;
        e.last_used = clock;
        if e.from_prefetch {
            e.from_prefetch = false;
            self.metrics.prefetch_useful += 1;
        }
        self.metrics.hits += 1;
        Some(e.expert.clone())
    }

    fn restore_and_cache(&mut self, block: usize, slot: usize) -> Result<Arc<ExpertWeights>> {
        let clock = self.clock;
        let restored = if self.store.is_some() {
            // Err, not panic: a CRC-valid artifact whose expert map is
            // shorter than the backbone router's slot count must fail this
            // request, not poison the cache mutex for every later one.
            let eidx = self.expert_index(block, slot).ok_or_else(|| {
                anyhow::anyhow!("artifact expert map has no entry for block {block} slot {slot}")
            })?;
            let compressed = self.shard_expert(block, eidx)?;
            let layer = self.layers.get(&block).expect("block not compressed");
            let t0 = std::time::Instant::now();
            let restored = Arc::new(layer.restore_expert_from(&compressed));
            self.metrics.restore_ns += t0.elapsed().as_nanos() as u64;
            restored
        } else {
            let layer = self.layers.get(&block).expect("block not compressed");
            let t0 = std::time::Instant::now();
            let restored = Arc::new(layer.restore_expert(slot));
            self.metrics.restore_ns += t0.elapsed().as_nanos() as u64;
            restored
        };
        let bytes = expert_bytes(&restored);
        // Evict LRU entries until the new expert fits (a single expert
        // larger than the whole budget is allowed in alone). Only dense
        // residents count here — paged shards are trimmed separately below
        // so the dense working set evolves identically to monolithic mode.
        while self.used_bytes + bytes > self.budget_bytes && !self.entries.is_empty() {
            let (&victim, _) = self
                .entries
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .expect("nonempty");
            let removed = self.entries.remove(&victim).unwrap();
            self.used_bytes -= removed.bytes;
            self.metrics.evictions += 1;
        }
        self.used_bytes += bytes;
        self.entries.insert(
            (block, slot),
            Entry { expert: restored.clone(), bytes, last_used: clock, from_prefetch: false },
        );
        self.trim_shards();
        Ok(restored)
    }

    /// Evict paged shards (LRU) until dense + paged fit the budget.
    fn trim_shards(&mut self) {
        while self.used_bytes + self.shard_used_bytes > self.budget_bytes
            && !self.shards.is_empty()
        {
            self.evict_lru_shard();
        }
    }

    fn evict_lru_shard(&mut self) {
        let victim = self
            .shards
            .iter()
            .min_by_key(|(_, e)| e.last_used)
            .map(|(&k, _)| k);
        if let Some(victim) = victim {
            let removed = self.shards.remove(&victim).unwrap();
            self.shard_used_bytes -= removed.bytes;
            self.metrics.shard_evictions += 1;
        }
    }

    /// Paged compressed expert for `(block, expert index)` — fetch + decode
    /// from the backing store on first touch, LRU thereafter.
    fn shard_expert(&mut self, block: usize, eidx: usize) -> Result<Arc<CompressedExpert>> {
        let clock = self.clock;
        if let Some(s) = self.shards.get_mut(&(block, eidx)) {
            s.last_used = clock;
            if s.from_prefetch {
                s.from_prefetch = false;
                self.metrics.prefetch_useful += 1;
            }
            return Ok(s.expert.clone());
        }
        let store = self.store.clone().expect("shard_expert requires store mode");
        let t0 = std::time::Instant::now();
        let expert = Arc::new(store.load_expert(block, eidx)?);
        self.metrics.shard_fetch_ns += t0.elapsed().as_nanos() as u64;
        self.metrics.shard_fetches += 1;
        let bytes = expert.memory_bytes();
        self.metrics.shard_bytes += bytes as u64;
        // Make room among the paged shards (never evicts dense residents —
        // they are the hot set the cost model chose to keep).
        while self.used_bytes + self.shard_used_bytes + bytes > self.budget_bytes
            && !self.shards.is_empty()
        {
            self.evict_lru_shard();
        }
        self.shard_used_bytes += bytes;
        self.shards.insert(
            (block, eidx),
            ShardEntry {
                expert: expert.clone(),
                fused: None,
                bytes,
                last_used: clock,
                from_prefetch: false,
            },
        );
        Ok(expert)
    }

    /// The lazily-split fused pieces of a paged expert.
    fn fused_shard_expert(&mut self, block: usize, slot: usize) -> Result<Arc<FusedExpert>> {
        let eidx = self.expert_index(block, slot).ok_or_else(|| {
            anyhow::anyhow!("artifact expert map has no entry for block {block} slot {slot}")
        })?;
        let (arch, d_model) = {
            let layer = self.layers.get(&block).expect("block not compressed");
            (layer.arch, layer.d_model)
        };
        let compressed = self.shard_expert(block, eidx)?;
        let entry = self.shards.get_mut(&(block, eidx)).expect("just paged in");
        if let Some(fused) = &entry.fused {
            return Ok(fused.clone());
        }
        // Split pieces are real memory (~ the compressed residual again):
        // charge them to the entry so paged_bytes reports the truth and
        // eviction releases the full footprint.
        let fused = Arc::new(compressed.fused(arch, d_model));
        let extra = fused.memory_bytes();
        entry.fused = Some(fused.clone());
        entry.bytes += extra;
        self.shard_used_bytes += extra;
        self.trim_shards();
        Ok(fused)
    }

    /// The restore-vs-fused cost model (EXPERIMENTS.md §Perf). Restoring
    /// materializes `pI × D` floats once and makes every later hit free;
    /// fused forwards pay O(nnz)/O(rank) extra per call but never touch the
    /// budget. Restore therefore wins iff the dense expert is likely to be
    /// resident when the next request for it arrives — or the current
    /// sub-batch alone amortizes the materialization.
    fn should_restore(&self, block: usize, slot: usize, batch_tokens: usize) -> bool {
        // 1. A large enough sub-batch amortizes the restore immediately.
        if batch_tokens >= RESTORE_AMORTIZE_TOKENS {
            return true;
        }
        let bytes = self.restored_bytes(block, slot);
        // 2. Fits without evicting anyone → it will stick; restore.
        if self.used_bytes + bytes <= self.budget_bytes {
            return true;
        }
        // 3. Larger than the whole budget → guaranteed thrash; stay fused.
        if bytes > self.budget_bytes {
            return false;
        }
        // 4. Tight budget: evict colder residents only for keys with shown
        //    reuse — a cold expert would displace a hotter one just to be
        //    displaced right back.
        self.heat.get(&(block, slot)).copied().unwrap_or(0) >= HOT_ACCESSES
    }

    /// Bytes a restored dense expert for `(block, slot)` would occupy
    /// (pI·D design params + b2), computed without restoring — in store
    /// mode from the artifact index, so no shard fetch is needed.
    fn restored_bytes(&self, block: usize, slot: usize) -> usize {
        let layer = self.layers.get(&block).expect("block not compressed");
        if let Some(store) = &self.store {
            let entry = store.layer_entry(block).expect("stored layer");
            return (entry.design_rows * entry.design_cols + layer.d_model) * 4;
        }
        let e = &layer.experts[layer.expert_map[slot]];
        let (pi, d) = e.residual.design_shape();
        (pi * d + e.b2.len()) * 4
    }

    fn fused_layer(&mut self, block: usize) -> Option<Arc<FusedLayer>> {
        if let Some(f) = self.fused.get(&block) {
            return f.clone();
        }
        let built = self
            .layers
            .get(&block)
            .expect("block not compressed")
            .fused()
            .map(Arc::new);
        self.fused.insert(block, built.clone());
        built
    }

    /// Store mode: the densified center expert of `block` (`None` when the
    /// layer has no shared center).
    fn fused_center(&mut self, block: usize) -> Option<Arc<ExpertWeights>> {
        if let Some(c) = self.fused_centers.get(&block) {
            return c.clone();
        }
        let built = self
            .layers
            .get(&block)
            .expect("block not compressed")
            .fused_center()
            .map(Arc::new);
        self.fused_centers.insert(block, built.clone());
        built
    }

    fn bump_heat(&mut self, key: Key) {
        self.serve_accesses += 1;
        let h = self.heat.entry(key).or_insert(0);
        *h = h.saturating_add(1);
        if self.serve_accesses % HEAT_DECAY_PERIOD == 0 {
            for v in self.heat.values_mut() {
                *v /= 2;
            }
            self.heat.retain(|_, v| *v > 0);
        }
    }

    /// Pre-warm the cache for the given (block, slot) pairs (the scheduler
    /// calls this with router predictions). Synchronous: monolithic mode
    /// restores dense experts, store mode pages the residual shards in.
    /// Effectiveness lands in [`CacheMetrics::prefetch_hits`] /
    /// [`CacheMetrics::prefetch_misses`] / [`CacheMetrics::prefetch_useful`]
    /// — demand hit/miss counters are NOT touched, so the serving hit rate
    /// stays attributable to the request stream.
    pub fn prefetch(&mut self, keys: &[Key]) {
        for &(b, s) in keys {
            if !self.has_layer(b) {
                continue;
            }
            self.clock += 1;
            if self.is_resident(b, s) {
                self.metrics.prefetch_hits += 1;
                self.touch(b, s);
                continue;
            }
            self.metrics.prefetch_misses += 1;
            if self.store.is_some() {
                let Some(eidx) = self.expert_index(b, s) else { continue };
                if self.shard_expert(b, eidx).is_ok() {
                    if let Some(e) = self.shards.get_mut(&(b, eidx)) {
                        e.from_prefetch = true;
                    }
                } else {
                    self.metrics.prefetch_dropped += 1;
                }
            } else if self.restore_and_cache(b, s).is_ok() {
                if let Some(e) = self.entries.get_mut(&(b, s)) {
                    e.from_prefetch = true;
                }
            }
        }
    }

    /// Refresh the LRU stamp of a resident key without counting a demand
    /// hit.
    fn touch(&mut self, block: usize, slot: usize) {
        let clock = self.clock;
        if let Some(e) = self.entries.get_mut(&(block, slot)) {
            e.last_used = clock;
            return;
        }
        if let Some(eidx) = self.expert_index(block, slot) {
            if let Some(s) = self.shards.get_mut(&(block, eidx)) {
                s.last_used = clock;
            }
        }
    }

    /// Plan an async prefetch: record hit/miss metrics for `keys`
    /// ((block, slot) pairs) and return the deduplicated
    /// (block, expert-index) pairs that actually need a fetch. Keys whose
    /// shard is resident OR already being fetched (`in_flight`, keyed by
    /// (block, expert index)) count as prefetch hits — the original miss
    /// was recorded when the fetch was scheduled, so usefulness stays an
    /// honest per-load ratio. The [`crate::store::Prefetcher`] decodes the
    /// returned keys off-thread and hands results back through
    /// [`ExpertCache::insert_prefetched`].
    pub fn plan_prefetch(
        &mut self,
        keys: &[Key],
        in_flight: &std::collections::HashSet<Key>,
    ) -> Vec<Key> {
        let mut out = Vec::new();
        for &(b, s) in keys {
            if !self.has_layer(b) {
                continue;
            }
            let Some(eidx) = self.expert_index(b, s) else { continue };
            if self.entries.contains_key(&(b, s))
                || self.shards.contains_key(&(b, eidx))
                || in_flight.contains(&(b, eidx))
                || out.contains(&(b, eidx))
            {
                self.metrics.prefetch_hits += 1;
                // Refresh the resident entry's LRU stamp (as sync prefetch
                // does): the prediction says this key is imminently needed,
                // so it must not be the eviction victim of the very fetches
                // this plan schedules.
                self.clock += 1;
                self.touch(b, s);
            } else {
                self.metrics.prefetch_misses += 1;
                out.push((b, eidx));
            }
        }
        out
    }

    /// Install a shard decoded by the async prefetcher. Never evicts dense
    /// residents: if the budget is full of demand entries the result is
    /// dropped (recorded in [`CacheMetrics::prefetch_dropped`]) rather than
    /// displacing proven-hot state with a prediction.
    pub fn insert_prefetched(&mut self, block: usize, eidx: usize, expert: CompressedExpert) {
        if self.store.is_none() || self.shards.contains_key(&(block, eidx)) {
            self.metrics.prefetch_dropped += 1;
            return;
        }
        let bytes = expert.memory_bytes();
        // Can it fit at all beside the dense residents? If not, drop the
        // prediction BEFORE touching the shard pool — evicting every
        // demand-proven shard only to discard the result anyway would be
        // pure churn.
        if self.used_bytes + bytes > self.budget_bytes {
            self.metrics.prefetch_dropped += 1;
            return;
        }
        while self.used_bytes + self.shard_used_bytes + bytes > self.budget_bytes
            && !self.shards.is_empty()
        {
            self.evict_lru_shard();
        }
        self.clock += 1;
        self.metrics.shard_fetches += 1;
        self.metrics.shard_bytes += bytes as u64;
        self.shard_used_bytes += bytes;
        self.shards.insert(
            (block, eidx),
            ShardEntry {
                expert: Arc::new(expert),
                fused: None,
                bytes,
                last_used: self.clock,
                from_prefetch: true,
            },
        );
    }

    pub fn resident_experts(&self) -> usize {
        self.entries.len()
    }

    /// Paged shards currently resident (store mode).
    pub fn resident_shards(&self) -> usize {
        self.shards.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::quick_compress;
    use crate::compress::{center_shared_act, fused_forward_expert, ResMoE};
    use crate::moe::{ExpertArch, MoeLayer};
    use crate::store::{pack_compressed_model, ExpertStore};
    use crate::util::Rng;

    fn compressed(seed: u64) -> (MoeLayer, CompressedLayer) {
        let mut rng = Rng::new(seed);
        let l = MoeLayer::random(ExpertArch::Relu, 8, 16, 4, 2, true, false, &mut rng);
        let cl = quick_compress(&ResMoE::up(), &l, 0.25, seed);
        (l, cl)
    }

    fn one_expert_bytes() -> usize {
        // relu p=8 pi=16 → (16*8 + 16 + 8*16 + 8) * 4
        (16 * 8 + 16 + 8 * 16 + 8) * 4
    }

    #[test]
    fn restores_correct_experts() {
        let (l, cl) = compressed(1);
        let mut cache = ExpertCache::new(vec![(3, cl.clone())], usize::MAX);
        for slot in 0..4 {
            let e = cache.get(3, slot);
            let direct = cl.restore_expert(slot);
            assert_eq!(*e, direct);
        }
        let _ = l;
        assert_eq!(cache.metrics.misses, 4);
        assert_eq!(cache.metrics.hits, 0);
    }

    #[test]
    fn hits_after_warm() {
        let (_, cl) = compressed(2);
        let mut cache = ExpertCache::new(vec![(0, cl)], usize::MAX);
        cache.get(0, 1);
        cache.get(0, 1);
        cache.get(0, 1);
        assert_eq!(cache.metrics.hits, 2);
        assert_eq!(cache.metrics.misses, 1);
        assert!(cache.metrics.hit_rate() > 0.6);
    }

    #[test]
    fn budget_forces_eviction_lru_order() {
        let (_, cl) = compressed(3);
        // Budget for exactly two restored experts.
        let mut cache = ExpertCache::new(vec![(0, cl)], 2 * one_expert_bytes());
        cache.get(0, 0);
        cache.get(0, 1);
        assert_eq!(cache.resident_experts(), 2);
        cache.get(0, 0); // refresh 0 → LRU victim is 1
        cache.get(0, 2); // evicts 1
        assert_eq!(cache.metrics.evictions, 1);
        cache.get(0, 0); // still resident → hit
        assert_eq!(cache.metrics.hits, 2);
        cache.get(0, 1); // miss again (was evicted)
        assert_eq!(cache.metrics.misses, 4);
    }

    #[test]
    fn tiny_budget_still_serves() {
        let (_, cl) = compressed(4);
        let mut cache = ExpertCache::new(vec![(0, cl)], 1);
        let e = cache.get(0, 3);
        assert!(e.n_params() > 0);
        assert_eq!(cache.resident_experts(), 1); // single over-budget entry allowed
    }

    #[test]
    fn prefetch_warms_and_records_metrics() {
        let (_, cl) = compressed(5);
        let mut cache = ExpertCache::new(vec![(2, cl)], usize::MAX);
        cache.prefetch(&[(2, 0), (2, 1), (9, 0)]); // block 9 ignored
        assert_eq!(cache.resident_experts(), 2);
        assert_eq!(cache.metrics.prefetch_misses, 2);
        assert_eq!(cache.metrics.prefetch_hits, 0);
        // Prefetch must not pollute the demand counters...
        assert_eq!(cache.metrics.hits, 0);
        assert_eq!(cache.metrics.misses, 0);
        cache.get(2, 0);
        assert_eq!(cache.metrics.hits, 1);
        // ...and a demanded prefetched entry counts as useful exactly once.
        cache.get(2, 0);
        assert_eq!(cache.metrics.prefetch_useful, 1);
        // Re-prefetching a resident key is a prefetch hit.
        cache.prefetch(&[(2, 1)]);
        assert_eq!(cache.metrics.prefetch_hits, 1);
        assert!(cache.metrics.prefetch_usefulness() > 0.0);
    }

    #[test]
    fn serve_restores_when_budget_has_room() {
        let (_, cl) = compressed(7);
        let mut cache = ExpertCache::new(vec![(0, cl.clone())], usize::MAX);
        let Serve::Dense(e) = cache.serve(0, 1, 4) else {
            panic!("room in budget must restore")
        };
        assert_eq!(*e, cl.restore_expert(1));
        assert_eq!(cache.metrics.restore_serves, 1);
        assert_eq!(cache.resident_experts(), 1);
        // Second serve is a hit, not a new decision.
        let Serve::Dense(_) = cache.serve(0, 1, 4) else { panic!("hit") };
        assert_eq!(cache.metrics.hits, 1);
        assert_eq!(cache.metrics.restore_serves, 1);
    }

    #[test]
    fn serve_goes_fused_under_thrash_budget() {
        // Budget below one restored expert: every miss must take the fused
        // path and never evict/restore.
        let (_, cl) = compressed(8);
        let budget = one_expert_bytes() / 2;
        let mut cache = ExpertCache::new(vec![(0, cl.clone())], budget);
        let mut rng = Rng::new(1);
        let x = crate::tensor::Matrix::randn(5, 8, 1.0, &mut rng);
        for slot in [0usize, 1, 2, 3, 0, 1] {
            match cache.serve(0, slot, x.rows) {
                Serve::Fused(fl) => {
                    let shared = fl.shared_act(&x);
                    let got = fl.forward_slot(slot, &x, &shared);
                    let want = cl.restore_expert(slot).forward(&x);
                    assert!(got.sq_dist(&want) < 1e-8, "slot {slot}");
                }
                _ => panic!("thrash budget must serve fused"),
            }
        }
        assert_eq!(cache.metrics.fused_serves, 6);
        assert_eq!(cache.metrics.restore_serves, 0);
        assert_eq!(cache.metrics.evictions, 0);
        assert_eq!(cache.used_bytes(), 0);
        // The fused state is accounted: roughly one densified center plus
        // the compressed residual pieces, and it is reported, not budgeted.
        let fb = cache.fused_bytes();
        assert!(fb >= one_expert_bytes(), "fused state includes the dense center: {fb}");
        assert!(fb < 4 * one_expert_bytes(), "fused state must stay near compressed size: {fb}");
    }

    #[test]
    fn serve_restores_hot_keys_on_tight_budget() {
        // Budget for one expert, two slots competing: the repeatedly-hit
        // slot earns a restore after HOT_ACCESSES, the cold one stays fused.
        let (_, cl) = compressed(9);
        let mut cache = ExpertCache::new(vec![(0, cl)], one_expert_bytes());
        // Fill the single cache slot with expert 3.
        assert!(matches!(cache.serve(0, 3, 1), Serve::Dense(_)));
        // Expert 0 is cold: first misses go fused...
        assert!(matches!(cache.serve(0, 0, 1), Serve::Fused(_)));
        assert!(matches!(cache.serve(0, 0, 1), Serve::Fused(_)));
        // ...until its heat crosses the threshold and it earns the eviction.
        assert!(matches!(cache.serve(0, 0, 1), Serve::Dense(_)));
        assert_eq!(cache.metrics.evictions, 1);
        assert_eq!(cache.metrics.fused_serves, 2);
        assert_eq!(cache.metrics.restore_serves, 2);
    }

    #[test]
    fn serve_big_batches_restore_even_when_thrashing() {
        let (_, cl) = compressed(10);
        let mut cache = ExpertCache::new(vec![(0, cl)], 1);
        assert!(matches!(cache.serve(0, 2, 4096), Serve::Dense(_)));
        assert_eq!(cache.metrics.restore_serves, 1);
    }

    #[test]
    fn serve_with_fused_disabled_always_restores() {
        let (_, cl) = compressed(11);
        let mut cache = ExpertCache::new(vec![(0, cl)], 1);
        cache.set_fused_enabled(false);
        for slot in 0..4 {
            assert!(matches!(cache.serve(0, slot, 1), Serve::Dense(_)));
        }
        assert_eq!(cache.metrics.restore_serves, 4);
        assert_eq!(cache.metrics.fused_serves, 0);
    }

    #[test]
    fn compressed_bytes_below_restored() {
        let (l, cl) = compressed(6);
        let cache = ExpertCache::new(vec![(0, cl)], usize::MAX);
        assert!(cache.compressed_bytes() < l.expert_params() * 4);
    }

    // ------------------------------------------------ backing-store mode

    fn store_cache(seed: u64, budget: usize) -> (CompressedLayer, ExpertCache) {
        let mut rng = Rng::new(seed);
        let mut cfg = crate::moe::ModelConfig::switch_mini(4);
        cfg.d_model = 8;
        cfg.d_inner = 16;
        cfg.n_layers = 2;
        cfg.n_heads = 2;
        cfg.vocab_size = 32;
        cfg.max_seq = 16;
        let model = crate::moe::Model::random(&cfg, &mut rng);
        let l = MoeLayer::random(ExpertArch::Relu, 8, 16, 4, 2, true, false, &mut rng);
        let cl = quick_compress(&ResMoE::up(), &l, 0.25, seed);
        let dir = std::env::temp_dir().join("resmoe-cache-store-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("cache-{seed}.rmes"));
        pack_compressed_model(&model, &[(1, cl.clone())], 0.25, &path).unwrap();
        let store = Arc::new(ExpertStore::open(&path).unwrap());
        let cache = ExpertCache::from_store(store, budget).unwrap();
        (cl, cache)
    }

    #[test]
    fn store_mode_pages_only_demanded_shards() {
        let (cl, mut cache) = store_cache(30, usize::MAX);
        // Skeleton resident, no experts paged yet.
        assert_eq!(cache.resident_shards(), 0);
        assert!(cache.compressed_bytes() > 0);
        let e = cache.get(1, 2);
        assert_eq!(*e, cl.restore_expert(2));
        assert_eq!(cache.metrics.shard_fetches, 1);
        assert_eq!(cache.resident_shards(), 1);
        // Same expert again: dense hit, no second fetch.
        cache.get(1, 2);
        assert_eq!(cache.metrics.shard_fetches, 1);
        assert_eq!(cache.metrics.hits, 1);
        // Different slot mapping to a different expert fetches its shard.
        cache.get(1, 0);
        assert_eq!(cache.metrics.shard_fetches, 2);
    }

    #[test]
    fn store_mode_paged_serve_matches_restore() {
        let (cl, mut cache) = store_cache(31, 0);
        let mut rng = Rng::new(2);
        let x = crate::tensor::Matrix::randn(5, 8, 1.0, &mut rng);
        for slot in [0usize, 1, 2, 3, 1, 0] {
            match cache.serve(1, slot, x.rows) {
                Serve::Paged { center, expert } => {
                    let sh = center_shared_act(&center, &x);
                    let got = fused_forward_expert(&center, &expert, &x, &sh);
                    let want = cl.restore_expert(slot).forward(&x);
                    assert!(got.sq_dist(&want) < 1e-8, "slot {slot}");
                }
                _ => panic!("zero budget in store mode must serve paged"),
            }
        }
        assert_eq!(cache.metrics.fused_serves, 6);
        assert_eq!(cache.metrics.restore_serves, 0);
        assert_eq!(cache.used_bytes(), 0);
        // Paged shards were still fetched (and stayed within... budget 0
        // admits a single over-budget shard at a time).
        assert!(cache.metrics.shard_fetches >= 4);
    }

    #[test]
    fn store_mode_budget_bounds_paged_bytes() {
        // Budget = one restored expert: paged shards must never push total
        // resident bytes past it (beyond the single-entry allowance).
        let (_, mut cache) = store_cache(32, one_expert_bytes());
        for slot in [0usize, 1, 2, 3, 0, 1, 2, 3] {
            cache.serve(1, slot, 1);
            assert!(
                cache.resident_shards() <= 4,
                "shards never exceed expert count"
            );
        }
        assert!(cache.metrics.shard_evictions > 0, "tight budget must evict shards");
        // A shard alone is far below one dense expert, so several fit, but
        // the pool stays bounded by the budget.
        assert!(cache.paged_bytes() + cache.used_bytes() <= one_expert_bytes() * 2);
    }

    #[test]
    fn store_mode_sync_prefetch_pages_shards() {
        let (_, mut cache) = store_cache(33, usize::MAX);
        cache.prefetch(&[(1, 0), (1, 3), (1, 0)]);
        assert_eq!(cache.resident_shards(), 2);
        assert_eq!(cache.resident_experts(), 0, "store-mode prefetch pages, not restores");
        assert_eq!(cache.metrics.prefetch_misses, 2);
        assert_eq!(cache.metrics.prefetch_hits, 1);
        // Demand serve of a prefetched shard is useful and fetch-free.
        let fetches = cache.metrics.shard_fetches;
        cache.serve(1, 0, 1);
        assert_eq!(cache.metrics.shard_fetches, fetches);
        assert_eq!(cache.metrics.prefetch_useful, 1);
    }

    #[test]
    fn store_mode_plan_and_insert_prefetched() {
        let (cl, mut cache) = store_cache(34, usize::MAX);
        let none = std::collections::HashSet::new();
        let plan = cache.plan_prefetch(&[(1, 0), (1, 2), (9, 0), (1, 0)], &none);
        assert_eq!(plan.len(), 2, "deduped, unknown block dropped: {plan:?}");
        assert_eq!(cache.metrics.prefetch_misses, 2, "batch duplicate is a hit, not a miss");
        assert_eq!(cache.metrics.prefetch_hits, 1);
        // A key already being fetched elsewhere is a hit too.
        let inflight: std::collections::HashSet<_> = [(1usize, 3usize)].into_iter().collect();
        assert!(cache.plan_prefetch(&[(1, 3)], &inflight).is_empty());
        assert_eq!(cache.metrics.prefetch_hits, 2);
        // Simulate the worker: decode off-thread, hand back.
        let store = cache.backing_store().unwrap().clone();
        for (b, eidx) in plan {
            let expert = store.load_expert(b, eidx).unwrap();
            cache.insert_prefetched(b, eidx, expert);
        }
        assert_eq!(cache.resident_shards(), 2);
        // Demand path finds them without new fetches through the cache.
        let before = cache.metrics.hits;
        let e = cache.get(1, 0);
        assert_eq!(*e, cl.restore_expert(0));
        assert_eq!(cache.metrics.hits, before);
        assert!(cache.metrics.prefetch_useful >= 1);
        // Duplicate insert is dropped.
        let dup = store.load_expert(1, 0).unwrap();
        cache.insert_prefetched(1, 0, dup);
        assert_eq!(cache.metrics.prefetch_dropped, 1);
    }

    #[test]
    fn store_mode_insert_prefetched_never_evicts_dense() {
        let (_, mut cache) = store_cache(35, one_expert_bytes());
        // Fill the budget with a demanded dense expert.
        cache.serve(1, 0, 4096);
        assert_eq!(cache.resident_experts(), 1);
        let store = cache.backing_store().unwrap().clone();
        let expert = store.load_expert(1, 1).unwrap();
        let dropped_before = cache.metrics.prefetch_dropped;
        cache.insert_prefetched(1, 1, expert);
        assert_eq!(cache.resident_experts(), 1, "dense resident untouched");
        assert_eq!(cache.metrics.prefetch_dropped, dropped_before + 1);
    }
}
