//! L3 serving coordinator: cross-request continuous batching (admission
//! windows, one fused forward per window, bit-identical to serial for
//! prefill), iteration-level decode batching over a paged KV cache
//! (relaxed parity — see `server.rs` module docs), a thread-pool server,
//! and the restored-expert LRU cache that turns the paper's Algorithm 2
//! into a first-class runtime feature ("barycenter resident, residuals
//! restored on router demand under a byte budget").

pub mod batcher;
pub mod cache;
pub mod demo;
pub mod metrics;
pub mod server;

pub use batcher::{
    next_window, poll_window, BatchPolicy, Batcher, DecodeFinished, DecodePolicy,
    DecodeScheduler, FlushReason, Window,
};
pub use cache::{classify_error, CacheMetrics, ErrorClass, ExpertCache, Serve};
pub use metrics::{
    batch_summary, cache_summary, decode_summary, BatchMetrics, DecodeMetrics, ServerMetrics,
    ServerStats,
};
pub use server::{Engine, Request, Response, Server, ServerConfig};
