//! Evaluation integration against the PRETRAINED checkpoints: the learned
//! model must beat chance, and compression quality must order the same way
//! the paper's Tables 2–3 do. Skips gracefully before `make artifacts`.

use resmoe::compress::compress_model;
use resmoe::eval::{self, method_by_name, Assets};
use resmoe::moe::ModelConfig;
use resmoe::Rng;

/// Shortened validation slice — integration tests must stay fast even in
/// dev builds; the benches use the full stream.
fn valid_slice(assets: &Assets) -> &[u32] {
    &assets.valid[..2048.min(assets.valid.len())]
}

fn pretrained_or_skip(name: &str) -> Option<Assets> {
    let cfg = ModelConfig::by_name(name)?;
    let assets = Assets::load(&cfg);
    if !assets.pretrained {
        eprintln!("SKIP eval integration: no pretrained {name} (run `make artifacts`)");
        return None;
    }
    Some(assets)
}

#[test]
fn pretrained_lm_beats_chance() {
    let Some(assets) = pretrained_or_skip("mixtral-mini") else { return };
    let ppl = eval::perplexity(&assets.model, valid_slice(&assets), 128);
    // Uniform over 256 tokens would be PPL 256; the corpus is highly
    // structured so a trained model lands far below.
    assert!(ppl < 64.0, "pretrained PPL {ppl} suspiciously high");
    let lam = eval::lambada_accuracy(&assets.model, &assets.lambada(60));
    assert!(lam > 1.5 / 256.0 * 10.0, "lambada acc {lam} at chance level");
}

#[test]
fn compression_preserves_most_quality_at_25pct() {
    let Some(assets) = pretrained_or_skip("mixtral-mini") else { return };
    let base_ppl = eval::perplexity(&assets.model, valid_slice(&assets), 128);
    let mut rng = Rng::new(0);
    let calib = assets.calibration_tokens(128);
    let resmoe = method_by_name("resmoe-up").unwrap();
    let cm = compress_model(&assets.model, resmoe.as_ref(), 0.25, 2, Some(&calib), &mut rng);
    let comp_ppl = eval::perplexity(&cm.model, valid_slice(&assets), 128);
    assert!(
        comp_ppl < base_ppl * 3.0,
        "resmoe-up PPL blew up: {base_ppl} -> {comp_ppl}"
    );
}

#[test]
fn table3_ordering_resmoe_beats_plain_up_and_svd() {
    let Some(assets) = pretrained_or_skip("mixtral-mini") else { return };
    let calib = assets.calibration_tokens(128);
    let ppl_of = |name: &str| {
        let comp = method_by_name(name).unwrap();
        let mut rng = Rng::new(1);
        let cm =
            compress_model(&assets.model, comp.as_ref(), 0.25, 2, Some(&calib), &mut rng);
        eval::perplexity(&cm.model, valid_slice(&assets), 128)
    };
    let resmoe_up = ppl_of("resmoe-up");
    let up = ppl_of("up-concat");
    let svd = ppl_of("svd-concat");
    let resmoe_svd = ppl_of("resmoe-svd");
    assert!(
        resmoe_up <= up * 1.05,
        "Table-3 shape violated: resmoe-up {resmoe_up} vs up {up}"
    );
    assert!(
        resmoe_svd <= svd * 1.05,
        "Table-3 shape violated: resmoe-svd {resmoe_svd} vs svd {svd}"
    );
}

#[test]
fn nlu_heads_beat_chance_on_switch() {
    let Some(assets) = pretrained_or_skip("switch-mini-8") else { return };
    for task in ["sst2", "mrpc", "cola"] {
        let Some(acc) = eval::task_accuracy(&assets.model, task, &assets.nlu_test(task, 120))
        else {
            eprintln!("SKIP: no head for {task}");
            continue;
        };
        assert!(acc > 0.55, "{task} head at chance: {acc}");
    }
}
