//! PJRT runtime integration: load the AOT artifacts lowered from JAX/Pallas
//! and verify their numerics against the rust-native implementation of the
//! same math. Skips (with a loud message) when `make artifacts` has not
//! run yet — the rest of the suite stays green without python.

use resmoe::runtime::{ArtifactInput, Manifest, PjrtRuntime};
use resmoe::util::stats::{softmax, top_k_indices};
use resmoe::Rng;

fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("RESMOE_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}

fn manifest_or_skip() -> Option<Manifest> {
    match Manifest::load(&artifacts_dir()) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIP runtime integration: {e} (run `make artifacts`)");
            None
        }
    }
}

/// rust-native reference of the dense-routing MoE block lowered in
/// `python/compile/model.py::moe_block_dense` (SwiGLU, all-experts compute,
/// softmax-over-top-k combine).
#[allow(clippy::too_many_arguments)]
fn native_moe_block_dense(
    x: &[f32],
    w_g: &[f32],
    w1: &[f32],
    b1: &[f32],
    w3: &[f32],
    b3: &[f32],
    w2: &[f32],
    b2: &[f32],
    (b, p, pi, n, top_k): (usize, usize, usize, usize, usize),
) -> Vec<f32> {
    let mut out = vec![0.0f32; b * p];
    for t in 0..b {
        let xt = &x[t * p..(t + 1) * p];
        // router
        let logits: Vec<f32> = (0..n)
            .map(|e| {
                let row = &w_g[e * p..(e + 1) * p];
                row.iter().zip(xt).map(|(a, b)| a * b).sum()
            })
            .collect();
        let sel = top_k_indices(&logits, top_k);
        let sel_logits: Vec<f32> = sel.iter().map(|&e| logits[e]).collect();
        let weights = softmax(&sel_logits);
        for (&e, &wgt) in sel.iter().zip(&weights) {
            // expert forward
            let w1e = &w1[e * pi * p..(e + 1) * pi * p];
            let w3e = &w3[e * pi * p..(e + 1) * pi * p];
            let w2e = &w2[e * p * pi..(e + 1) * p * pi];
            let mut h = vec![0.0f32; pi];
            for i in 0..pi {
                let mut a = b1[e * pi + i];
                let mut g = b3[e * pi + i];
                for j in 0..p {
                    a += w1e[i * p + j] * xt[j];
                    g += w3e[i * p + j] * xt[j];
                }
                let s = a / (1.0 + (-a).exp());
                h[i] = s * g;
            }
            for o in 0..p {
                let mut acc = b2[e * p + o];
                for i in 0..pi {
                    acc += w2e[o * pi + i] * h[i];
                }
                out[t * p + o] += wgt * acc;
            }
        }
    }
    out
}

#[test]
fn moe_block_dense_artifact_matches_native() {
    let Some(manifest) = manifest_or_skip() else { return };
    let Some(spec) = manifest.find("moe_block_dense_swiglu") else {
        eprintln!("SKIP: moe_block_dense_swiglu not in manifest");
        return;
    };
    let runtime = PjrtRuntime::cpu().expect("PJRT CPU client");
    let artifact = runtime.load(spec).expect("compile artifact");
    let g = &spec.meta;
    let (b, p, pi, n, top_k) = (
        g.get("geometry").unwrap().get("b").unwrap().as_usize().unwrap(),
        g.get("geometry").unwrap().get("p").unwrap().as_usize().unwrap(),
        g.get("geometry").unwrap().get("pi").unwrap().as_usize().unwrap(),
        g.get("geometry").unwrap().get("n").unwrap().as_usize().unwrap(),
        g.get("geometry").unwrap().get("top_k").unwrap().as_usize().unwrap(),
    );
    let mut rng = Rng::new(42);
    let bufs: Vec<Vec<f32>> = spec
        .inputs
        .iter()
        .map(|i| rng.normal_vec(i.shape.iter().product(), 0.5))
        .collect();
    let inputs: Vec<ArtifactInput> = spec
        .inputs
        .iter()
        .zip(&bufs)
        .map(|(s, b)| ArtifactInput::F32(b, s.shape.iter().map(|&d| d as i64).collect()))
        .collect();
    let got = artifact.execute_f32(&inputs).expect("execute");
    let want = native_moe_block_dense(
        &bufs[0], &bufs[1], &bufs[2], &bufs[3], &bufs[4], &bufs[5], &bufs[6], &bufs[7],
        (b, p, pi, n, top_k),
    );
    assert_eq!(got.len(), want.len());
    let max_err = got
        .iter()
        .zip(&want)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err < 1e-3, "max err {max_err}");
}

#[test]
fn resmoe_artifact_agrees_with_restored_dense_artifact() {
    // Algorithm-2 equivalence THROUGH THE WHOLE STACK: the factored
    // ResMoE(SVD) artifact (Pallas kernel inside) must match the dense
    // artifact run on explicitly restored weights.
    let Some(manifest) = manifest_or_skip() else { return };
    let (Some(dense), Some(fact)) = (
        manifest.find("moe_block_dense_swiglu"),
        manifest.find("moe_block_resmoe_swiglu"),
    ) else {
        eprintln!("SKIP: MoE block artifacts missing");
        return;
    };
    let runtime = PjrtRuntime::cpu().unwrap();
    let dense_art = runtime.load(dense).unwrap();
    let fact_art = runtime.load(fact).unwrap();
    let geom = fact.meta.get("geometry").unwrap();
    let get = |k: &str| geom.get(k).unwrap().as_usize().unwrap();
    let (b, p, pi, n, r) = (get("b"), get("p"), get("pi"), get("n"), get("rank"));
    let mut rng = Rng::new(7);
    // Factored inputs.
    let x = rng.normal_vec(b * p, 0.5);
    let w_g = rng.normal_vec(n * p, 0.5);
    let bw1 = rng.normal_vec(pi * p, 0.3);
    let bb1 = rng.normal_vec(pi, 0.1);
    let u1 = rng.normal_vec(n * pi * r, 0.1);
    let v1 = rng.normal_vec(n * r * p, 0.1);
    let bw3 = rng.normal_vec(pi * p, 0.3);
    let bb3 = rng.normal_vec(pi, 0.1);
    let u3 = rng.normal_vec(n * pi * r, 0.1);
    let v3 = rng.normal_vec(n * r * p, 0.1);
    let bw2 = rng.normal_vec(p * pi, 0.3);
    let u2 = rng.normal_vec(n * p * r, 0.1);
    let v2 = rng.normal_vec(n * r * pi, 0.1);
    let b2 = rng.normal_vec(n * p, 0.1);
    let fact_inputs: Vec<(&[f32], Vec<usize>)> = vec![
        (&x, vec![b, p]),
        (&w_g, vec![n, p]),
        (&bw1, vec![pi, p]),
        (&bb1, vec![pi]),
        (&u1, vec![n, pi, r]),
        (&v1, vec![n, r, p]),
        (&bw3, vec![pi, p]),
        (&bb3, vec![pi]),
        (&u3, vec![n, pi, r]),
        (&v3, vec![n, r, p]),
        (&bw2, vec![p, pi]),
        (&u2, vec![n, p, r]),
        (&v2, vec![n, r, pi]),
        (&b2, vec![n, p]),
    ];
    let fact_lits: Vec<ArtifactInput> = fact_inputs
        .iter()
        .map(|(d, s)| ArtifactInput::F32(d, s.iter().map(|&x| x as i64).collect()))
        .collect();
    let got_fact = fact_art.execute_f32(&fact_lits).unwrap();
    // Restore dense weights: W = base + U V per expert (row-major matmul).
    let restore = |base: &[f32], u: &[f32], v: &[f32], rows: usize, cols: usize| -> Vec<f32> {
        let mut out = Vec::with_capacity(n * rows * cols);
        for e in 0..n {
            for i in 0..rows {
                for j in 0..cols {
                    let mut acc = base[i * cols + j];
                    for k in 0..r {
                        acc += u[e * rows * r + i * r + k] * v[e * r * cols + k * cols + j];
                    }
                    out.push(acc);
                }
            }
        }
        out
    };
    let w1 = restore(&bw1, &u1, &v1, pi, p);
    let w3 = restore(&bw3, &u3, &v3, pi, p);
    let w2 = restore(&bw2, &u2, &v2, p, pi);
    let b1_full: Vec<f32> = (0..n).flat_map(|_| bb1.clone()).collect();
    let b3_full: Vec<f32> = (0..n).flat_map(|_| bb3.clone()).collect();
    let dense_inputs: Vec<(&[f32], Vec<usize>)> = vec![
        (&x, vec![b, p]),
        (&w_g, vec![n, p]),
        (&w1, vec![n, pi, p]),
        (&b1_full, vec![n, pi]),
        (&w3, vec![n, pi, p]),
        (&b3_full, vec![n, pi]),
        (&w2, vec![n, p, pi]),
        (&b2, vec![n, p]),
    ];
    let dense_lits: Vec<ArtifactInput> = dense_inputs
        .iter()
        .map(|(d, s)| ArtifactInput::F32(d, s.iter().map(|&x| x as i64).collect()))
        .collect();
    let got_dense = dense_art.execute_f32(&dense_lits).unwrap();
    let max_err = got_fact
        .iter()
        .zip(&got_dense)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err < 5e-3, "factored vs restored-dense max err {max_err}");
}

#[test]
fn lm_scorer_matches_native_model() {
    let Some(manifest) = manifest_or_skip() else { return };
    let model_name = "mixtral-mini";
    if manifest.lm_score_batches(model_name).is_empty() {
        eprintln!("SKIP: no lm_score artifacts for {model_name}");
        return;
    }
    let ckpt = artifacts_dir().join(format!("{model_name}.rmw"));
    if !ckpt.exists() {
        eprintln!("SKIP: checkpoint missing");
        return;
    }
    let runtime = PjrtRuntime::cpu().unwrap();
    let scorer = resmoe::runtime::LmScorer::load(&runtime, &manifest, model_name, &ckpt)
        .expect("scorer");
    let model = resmoe::moe::model_io::load_model(&ckpt).unwrap();
    let tokens: Vec<u32> = (1..40).map(|i| (i * 7 % 256) as u32).collect();
    let pjrt_lp = scorer.mean_log_prob(&tokens).unwrap();
    // Native reference.
    let logits = model.forward(&tokens);
    let mut total = 0.0f64;
    for i in 0..tokens.len() - 1 {
        let row = logits.row(i);
        total += (row[tokens[i + 1] as usize] - resmoe::util::stats::logsumexp(row)) as f64;
    }
    let native_lp = total / (tokens.len() - 1) as f64;
    assert!(
        (pjrt_lp - native_lp).abs() < 2e-3,
        "pjrt {pjrt_lp} vs native {native_lp}"
    );
}
