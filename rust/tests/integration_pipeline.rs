//! End-to-end compression pipeline integration: every registered method
//! compresses a full model, restores, and evaluates; ResMoE's headline
//! ordering claims hold on upcycled (Mixtral-like) experts.

use resmoe::compress::{compress_model, ResMoE};
use resmoe::eval::{method_by_name, ALL_METHODS};
use resmoe::moe::{Model, ModelConfig};
use resmoe::Rng;

fn mixtral_like(seed: u64) -> (Model, ModelConfig, Rng) {
    let mut cfg = ModelConfig::mixtral_mini();
    cfg.d_model = 16;
    cfg.d_inner = 56;
    cfg.n_layers = 3;
    cfg.n_heads = 2;
    cfg.vocab_size = 64;
    cfg.max_seq = 48;
    cfg.n_experts = 4;
    let mut rng = Rng::new(seed);
    let m = Model::random(&cfg, &mut rng);
    (m, cfg, rng)
}

#[test]
fn every_method_compresses_and_restores() {
    let (m, cfg, mut rng) = mixtral_like(1);
    let calib: Vec<u32> = (0..32).map(|i| (i * 5 % cfg.vocab_size) as u32).collect();
    let tokens: Vec<u32> = (0..24).map(|i| (i * 3 % cfg.vocab_size) as u32).collect();
    for name in ALL_METHODS {
        let comp = method_by_name(name).unwrap();
        let cm = compress_model(&m, comp.as_ref(), 0.25, 2, Some(&calib), &mut rng);
        assert_eq!(cm.layers.len(), 2, "{name}");
        assert!(cm.report.mean_approx_error().is_finite(), "{name}");
        assert!(
            cm.report.total_params_after() < cm.report.total_params_before(),
            "{name}: no reduction"
        );
        let logits = cm.model.forward(&tokens);
        assert!(
            logits.data.iter().all(|v| v.is_finite()),
            "{name}: non-finite output"
        );
    }
}

#[test]
fn resmoe_wins_table1_on_upcycled_experts() {
    // Table 1's qualitative claim: ResMoE(UP) attains the lowest
    // approximation error among all methods on Mixtral-style layers.
    let (m, _, _) = mixtral_like(2);
    let calib: Vec<u32> = (0..32).map(|i| (i % 60) as u32).collect();
    let mut errors = Vec::new();
    for name in ALL_METHODS {
        let comp = method_by_name(name).unwrap();
        let mut r = Rng::new(7);
        let cm = compress_model(&m, comp.as_ref(), 0.25, 2, Some(&calib), &mut r);
        errors.push((name, cm.report.mean_approx_error()));
    }
    let resmoe_up = errors.iter().find(|(n, _)| *n == "resmoe-up").unwrap().1;
    for (name, err) in &errors {
        if *name != "resmoe-up" {
            assert!(
                resmoe_up <= *err + 1e-12,
                "resmoe-up ({resmoe_up:.5}) should beat {name} ({err:.5})"
            );
        }
    }
}

#[test]
fn rate_sweep_is_monotone_for_resmoe() {
    // Figure 4's x-axis: error strictly improves with retention rate.
    let (m, _, mut rng) = mixtral_like(3);
    let mut prev = f64::INFINITY;
    for rate in [0.10, 0.25, 0.50, 0.75] {
        let cm = compress_model(&m, &ResMoE::up(), rate, 2, None, &mut rng);
        let err = cm.report.mean_approx_error();
        assert!(err <= prev + 1e-9, "rate {rate}: {err} > {prev}");
        prev = err;
    }
}

#[test]
fn compressed_model_output_degrades_gracefully() {
    // Relative output distortion should shrink as rate grows.
    let (m, cfg, mut rng) = mixtral_like(4);
    let tokens: Vec<u32> = (0..32).map(|i| (i * 7 % cfg.vocab_size) as u32).collect();
    let base = m.forward(&tokens);
    let mut dist = |rate: f64, rng: &mut Rng| {
        let cm = compress_model(&m, &ResMoE::up(), rate, 3, None, rng);
        cm.model.forward(&tokens).sq_dist(&base) / base.frob_norm_sq()
    };
    let lo = dist(0.1, &mut rng);
    let hi = dist(0.6, &mut rng);
    assert!(hi < lo, "rate 0.6 distortion {hi} should be below rate 0.1 {lo}");
}

#[test]
fn shared_expert_is_never_compressed() {
    // DeepSeek protocol (App. A.2): the shared expert stays intact.
    let mut cfg = ModelConfig::deepseek_mini();
    cfg.d_model = 16;
    cfg.d_inner = 11;
    cfg.n_layers = 2;
    cfg.n_heads = 2;
    cfg.vocab_size = 64;
    cfg.max_seq = 32;
    cfg.n_experts = 8;
    cfg.top_k = 2;
    let mut rng = Rng::new(5);
    let m = Model::random(&cfg, &mut rng);
    let cm = compress_model(&m, &ResMoE::up(), 0.25, 2, None, &mut rng);
    for (bi, _) in &cm.layers {
        let resmoe::moe::Ffn::Moe(orig) = &m.blocks[*bi].ffn else { panic!() };
        let resmoe::moe::Ffn::Moe(new) = &cm.model.blocks[*bi].ffn else { panic!() };
        assert_eq!(
            orig.shared_expert.as_ref().unwrap().w1,
            new.shared_expert.as_ref().unwrap().w1
        );
    }
}

#[test]
fn checkpoint_roundtrip_preserves_compressed_eval() {
    // save → load → compress must equal compress directly.
    let (m, _, mut rng) = mixtral_like(6);
    let dir = std::env::temp_dir().join("resmoe-integ");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("model.rmw");
    resmoe::moe::model_io::save_model(&m, &path).unwrap();
    let m2 = resmoe::moe::model_io::load_model(&path).unwrap();
    let cm1 = compress_model(&m, &ResMoE::up(), 0.25, 2, None, &mut Rng::new(9));
    let cm2 = compress_model(&m2, &ResMoE::up(), 0.25, 2, None, &mut Rng::new(9));
    assert!(
        (cm1.report.mean_approx_error() - cm2.report.mean_approx_error()).abs() < 1e-12
    );
    let _ = &mut rng;
}
