//! Differential property harness for cross-request continuous batching.
//!
//! The contract under test: executing a batch window through
//! `Engine::handle_batch` produces responses **byte-identical** to serving
//! the same requests one-at-a-time through `Engine::handle`, and the
//! cache's decision counters evolve identically — under every compression
//! method (UP/SVD), rate (including the 0 and 1 edges), cache budget
//! (roomy, tight, thrash), and engine mode (monolithic and packed/RMES).
//!
//! Why this can hold bitwise at all: every per-row kernel on the serving
//! path is row-independent, and the cache partitions its mutable state per
//! block, so the layer-major serve order of a batched window and the
//! request-major order of serial serving visit each block with the SAME
//! serve sequence (see `coordinator/cache.rs` module docs). The companion
//! seeded Python simulation (`scripts/sim_batching.py`) model-checks the
//! same commutativity over randomized decision traces, including a
//! counterexample showing the old globally-pooled budget would break it.
//!
//! Every engine here runs with iteration-level decode batching DISABLED
//! (`set_decode_batch(1)`): step-major decode interleaving deliberately
//! trades this bitwise theorem for throughput, and its relaxed contract
//! (per-token error bounds + conservation laws) is pinned separately in
//! `tests/prop_decode.rs`.

use resmoe::compress::{compress_model, CompressedModel, ResMoE};
use resmoe::coordinator::{CacheMetrics, Engine, Request, Response};
use resmoe::moe::{Model, ModelConfig};
use resmoe::store::pack_compressed_model;
use resmoe::util::prop::{check, PropConfig};
use resmoe::util::Rng;
use std::path::PathBuf;

/// 4 layers → MoE blocks 1 and 3: the two-block case is what exercises the
/// cross-layer serve reordering the per-block partitioning makes benign.
fn base_model(seed: u64) -> Model {
    let mut cfg = ModelConfig::switch_mini(4);
    cfg.d_model = 16;
    cfg.d_inner = 32;
    cfg.n_layers = 4;
    cfg.n_heads = 2;
    cfg.vocab_size = 32;
    cfg.max_seq = 32;
    let mut rng = Rng::new(seed);
    let mut m = Model::random(&cfg, &mut rng);
    m.heads.push((
        "cls".into(),
        resmoe::Matrix::randn(3, cfg.d_model, 0.2, &mut rng),
    ));
    m
}

/// One restored dense expert of the test geometry: design 32×(2·16+1) + b2.
fn one_expert_bytes() -> usize {
    (32 * (2 * 16 + 1) + 16) * 4
}

struct Combo {
    name: String,
    model: Model,
    cm: CompressedModel,
    artifact: PathBuf,
}

/// UP and SVD at rates {0, 0.25, 1} over the same backbone, each packed to
/// an RMES artifact once (cases below reopen engines per budget).
fn combos() -> Vec<Combo> {
    let dir = std::env::temp_dir().join("resmoe-prop-batching");
    std::fs::create_dir_all(&dir).unwrap();
    let model = base_model(1000);
    let mut out = Vec::new();
    for (mname, method) in [("up", ResMoE::up()), ("svd", ResMoE::svd())] {
        for rate in [0.0f64, 0.25, 1.0] {
            let mut rng = Rng::new(7 + (rate * 8.0) as u64);
            let cm = compress_model(&model, &method, rate, 2, None, &mut rng);
            let artifact = dir.join(format!("{mname}-{rate}.rmes"));
            pack_compressed_model(&model, &cm.layers, rate, &artifact).unwrap();
            out.push(Combo { name: format!("{mname}@{rate}"), model: model.clone(), cm, artifact });
        }
    }
    out
}

#[derive(Debug)]
struct Case {
    combo: usize,
    budget: usize,
    packed: bool,
    reqs: Vec<Request>,
}

fn gen_requests(rng: &mut Rng, with_sequential: bool) -> Vec<Request> {
    let n = 1 + rng.below(8); // 1–8 concurrent clients
    (0..n)
        .map(|_| match rng.below(if with_sequential { 10 } else { 8 }) {
            // varying token counts, incl. the 2-token minimum
            0..=5 => Request::Score {
                tokens: (0..2 + rng.below(9)).map(|_| rng.below(32) as u32).collect(),
            },
            6 | 7 => Request::Classify {
                task: "cls".into(),
                tokens: (0..1 + rng.below(8)).map(|_| rng.below(32) as u32).collect(),
            },
            _ => Request::Generate {
                prompt: (0..1 + rng.below(3)).map(|_| rng.below(32) as u32).collect(),
                max_new: rng.below(4),
            },
        })
        .collect()
}

fn budgets() -> [usize; 5] {
    let e = one_expert_bytes();
    // roomy / thrash / one-share-per-block tight / tighter / in between
    [usize::MAX, 0, 2 * e, 4 * e, 3 * e]
}

fn assert_decision_metrics_equal(a: &CacheMetrics, b: &CacheMetrics) -> Result<(), String> {
    let pairs = [
        ("hits", a.hits, b.hits),
        ("misses", a.misses, b.misses),
        ("evictions", a.evictions, b.evictions),
        ("restore_serves", a.restore_serves, b.restore_serves),
        ("fused_serves", a.fused_serves, b.fused_serves),
        ("restores_executed", a.restores_executed, b.restores_executed),
        ("shard_fetches", a.shard_fetches, b.shard_fetches),
        ("shard_evictions", a.shard_evictions, b.shard_evictions),
    ];
    for (name, sa, sb) in pairs {
        if sa != sb {
            return Err(format!("metric {name}: serial {sa} vs batched {sb}"));
        }
    }
    Ok(())
}

fn engines_for(case: &Case, combos: &[Combo]) -> (Engine, Engine) {
    let c = &combos[case.combo];
    let (mut serial, mut batched) = if case.packed {
        let mut serial = Engine::from_store(&c.artifact, case.budget).unwrap();
        serial.disable_prefetch(); // deterministic serve sequence both sides
        let mut batched = Engine::from_store(&c.artifact, case.budget).unwrap();
        batched.disable_prefetch();
        (serial, batched)
    } else {
        (
            Engine::compressed(c.model.clone(), c.cm.layers.clone(), case.budget),
            Engine::compressed(c.model.clone(), c.cm.layers.clone(), case.budget),
        )
    };
    // This harness pins the BIT-FOR-BIT theorem, which only holds with
    // iteration-level decode batching disabled: batching Generates
    // interleaves the stateful cost model's serve order, a divergence
    // covered by the RELAXED contract in tests/prop_decode.rs instead.
    serial.set_decode_batch(1);
    batched.set_decode_batch(1);
    (serial, batched)
}

#[test]
fn prop_batched_serve_matches_serial_bit_for_bit() {
    let combos = combos();
    let budgets = budgets();
    let n_combos = combos.len();
    check(
        PropConfig { cases: 40, seed: 0xBA7C4 },
        |rng| Case {
            combo: rng.below(n_combos),
            budget: budgets[rng.below(budgets.len())],
            packed: rng.below(2) == 1,
            reqs: gen_requests(rng, false),
        },
        |case| {
            let (serial, batched) = engines_for(case, &combos);
            let want: Vec<Response> = case.reqs.iter().map(|r| serial.handle(r)).collect();
            let got = batched.handle_batch(&case.reqs);
            if got != want {
                return Err(format!(
                    "{}: batched != serial\n got {got:?}\nwant {want:?}",
                    combos[case.combo].name
                ));
            }
            // Responses carry f64 scores — equality above is exact (bit
            // identity up to the one NaN-free comparison f64 provides).
            // Decision metrics must replay the serial reference ordering.
            assert_decision_metrics_equal(
                &serial.cache_metrics().unwrap(),
                &batched.cache_metrics().unwrap(),
            )
            .map_err(|e| format!("{} (budget {}): {e}", combos[case.combo].name, case.budget))
        },
    );
}

#[test]
fn prop_batched_windows_with_sequential_requests_match_serial() {
    // Generate requests split prefill runs; invalid requests are answered
    // inline. Whatever the mix, window execution equals serial order.
    let combos = combos();
    let budgets = budgets();
    let n_combos = combos.len();
    check(
        PropConfig { cases: 16, seed: 0xBA7C5 },
        |rng| {
            let mut reqs = gen_requests(rng, true);
            if rng.below(3) == 0 {
                // Splice in an invalid request at a random position.
                let at = rng.below(reqs.len() + 1);
                reqs.insert(at, Request::Score { tokens: vec![1] });
            }
            Case {
                combo: rng.below(n_combos),
                budget: budgets[rng.below(budgets.len())],
                packed: rng.below(2) == 1,
                reqs,
            }
        },
        |case| {
            let (serial, batched) = engines_for(case, &combos);
            let want: Vec<Response> = case.reqs.iter().map(|r| serial.handle(r)).collect();
            let got = batched.handle_batch(&case.reqs);
            if got != want {
                return Err(format!(
                    "{}: mixed window != serial\n got {got:?}\nwant {want:?}",
                    combos[case.combo].name
                ));
            }
            assert_decision_metrics_equal(
                &serial.cache_metrics().unwrap(),
                &batched.cache_metrics().unwrap(),
            )
        },
    );
}

#[test]
fn prop_consecutive_windows_compose_like_serial_streams() {
    // Splitting one request stream into several consecutive windows must
    // not change anything either: [w1; w2; w3] == serial(all) — the
    // between-window cache state is exactly the serial mid-stream state.
    let combos = combos();
    let n_combos = combos.len();
    let e = one_expert_bytes();
    check(
        PropConfig { cases: 12, seed: 0xBA7C6 },
        |rng| {
            let mut reqs = gen_requests(rng, false);
            reqs.extend(gen_requests(rng, false));
            Case {
                combo: rng.below(n_combos),
                budget: [usize::MAX, 2 * e, 0][rng.below(3)],
                packed: rng.below(2) == 1,
                reqs,
            }
        },
        |case| {
            let (serial, batched) = engines_for(case, &combos);
            let want: Vec<Response> = case.reqs.iter().map(|r| serial.handle(r)).collect();
            // Random-ish deterministic split derived from the case size.
            let cut = 1 + case.reqs.len() / 3;
            let cut2 = (cut + 1 + case.reqs.len() / 2).min(case.reqs.len());
            let mut got = batched.handle_batch(&case.reqs[..cut]);
            got.extend(batched.handle_batch(&case.reqs[cut..cut2]));
            got.extend(batched.handle_batch(&case.reqs[cut2..]));
            if got != want {
                return Err("window composition diverged from the serial stream".into());
            }
            assert_decision_metrics_equal(
                &serial.cache_metrics().unwrap(),
                &batched.cache_metrics().unwrap(),
            )
        },
    );
}
