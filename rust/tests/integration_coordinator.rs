//! Serving-coordinator integration: correctness of the cache-backed hot
//! path under concurrency, memory-budget behaviour, and batching policy.

use resmoe::compress::{compress_model, ResMoE};
use resmoe::coordinator::{Engine, Request, Response, Server, ServerConfig};
use resmoe::moe::{Model, ModelConfig};
use resmoe::Rng;
use std::time::Duration;

fn model(seed: u64) -> Model {
    let mut cfg = ModelConfig::switch_mini(4);
    cfg.d_model = 16;
    cfg.d_inner = 32;
    cfg.n_layers = 4;
    cfg.n_heads = 2;
    cfg.vocab_size = 32;
    cfg.max_seq = 40;
    let mut rng = Rng::new(seed);
    Model::random(&cfg, &mut rng)
}

fn compressed_engine(m: &Model, budget: usize, seed: u64) -> Engine {
    let mut rng = Rng::new(seed);
    let cm = compress_model(m, &ResMoE::up(), 0.25, 2, None, &mut rng);
    Engine::compressed(m.clone(), cm.layers, budget)
}

#[test]
fn concurrent_requests_equal_serial_answers() {
    let m = model(1);
    let engine = compressed_engine(&m, 1 << 22, 2);
    // Serial ground truth.
    let requests: Vec<Request> = (0..24)
        .map(|i| Request::Score {
            tokens: (0..10).map(|t| ((t * (i + 1)) % 32) as u32).collect(),
        })
        .collect();
    let want: Vec<Response> = requests.iter().map(|r| engine.handle(r)).collect();
    // Through the concurrent server.
    let server = Server::start(
        engine,
        ServerConfig { batch_max: 4, batch_wait_us: 100, workers: 3, ..Default::default() },
    );
    let replies: Vec<_> = requests.iter().map(|r| server.submit(r.clone())).collect();
    for (rx, want) in replies.into_iter().zip(want) {
        let (got, _) = rx.recv().unwrap();
        match (got, want) {
            (Response::Score(a), Response::Score(b)) => {
                assert!((a - b).abs() < 1e-9, "{a} vs {b}")
            }
            other => panic!("{other:?}"),
        }
    }
    server.shutdown();
}

#[test]
fn tiny_cache_budget_still_correct_just_slower() {
    let m = model(3);
    let roomy = compressed_engine(&m, usize::MAX, 4);
    let tiny = compressed_engine(&m, 1, 4); // thrashes: serves restore-free
    let tiny_restore = compressed_engine(&m, 1, 4); // seed policy for A/B
    tiny_restore.set_fused(false);
    let tokens: Vec<u32> = (0..12).map(|t| (t % 32) as u32).collect();
    // Repeat the request: the roomy cache turns later passes into hits; the
    // 1-byte cache keeps missing — fused by default, restoring with the
    // cost model off.
    let (mut a, mut b, mut c) = (
        Response::Error("".into()),
        Response::Error("".into()),
        Response::Error("".into()),
    );
    for _ in 0..3 {
        a = roomy.handle(&Request::Score { tokens: tokens.clone() });
        b = tiny.handle(&Request::Score { tokens: tokens.clone() });
        c = tiny_restore.handle(&Request::Score { tokens: tokens.clone() });
    }
    match (a, b, c) {
        (Response::Score(x), Response::Score(y), Response::Score(z)) => {
            // Fused reassociates float ops; restore-only is bit-identical.
            assert!((x - y).abs() < 1e-4, "{x} vs fused {y}");
            assert!((x - z).abs() < 1e-9, "{x} vs restored {z}");
        }
        other => panic!("{other:?}"),
    }
    let tm = tiny.cache_metrics().unwrap();
    let rm = roomy.cache_metrics().unwrap();
    let sm = tiny_restore.cache_metrics().unwrap();
    assert!(tm.misses > rm.misses, "tiny budget must miss more often");
    // New policy: a budget below one expert never restores or evicts —
    // every miss is served restore-free.
    assert!(tm.fused_serves > 0);
    assert_eq!(tm.evictions, 0);
    // Seed policy (fused off): same pressure shows up as restores+evictions.
    assert!(sm.restore_serves > 0);
    assert!(sm.evictions > 0);
}

#[test]
fn cache_hit_rate_improves_across_repeated_traffic() {
    let m = model(5);
    let engine = compressed_engine(&m, usize::MAX, 6);
    let tokens: Vec<u32> = (0..16).map(|t| (t % 32) as u32).collect();
    for _ in 0..5 {
        engine.handle(&Request::Score { tokens: tokens.clone() });
    }
    let cm = engine.cache_metrics().unwrap();
    assert!(cm.hit_rate() > 0.5, "hit rate {:.2}", cm.hit_rate());
}

#[test]
fn generate_and_classify_through_server() {
    let mut m = model(7);
    let mut rng = Rng::new(8);
    m.heads.push((
        "sst2".into(),
        resmoe::Matrix::randn(2, m.cfg.d_model, 0.2, &mut rng),
    ));
    let engine = compressed_engine(&m, usize::MAX, 9);
    let server = Server::start(engine, ServerConfig::default());
    let g = server.submit(Request::Generate { prompt: vec![1, 2, 3], max_new: 5 });
    let c = server.submit(Request::Classify { task: "sst2".into(), tokens: vec![4, 5, 6, 7] });
    match g.recv().unwrap().0 {
        Response::Generate(tokens) => assert_eq!(tokens.len(), 5),
        other => panic!("{other:?}"),
    }
    match c.recv().unwrap().0 {
        Response::Classify(label) => assert!(label < 2),
        other => panic!("{other:?}"),
    }
    let metrics = server.shutdown();
    assert_eq!(metrics.requests, 2);
}

#[test]
fn packed_engine_serves_concurrently_and_equals_monolithic() {
    // End-to-end artifact path: pack → open → serve through the threaded
    // server with a budget far below the decoded expert bytes. Every answer
    // must equal the monolithic engine's serial answer, shards must page in
    // on demand (never the whole file), and the prefetcher must be active.
    use resmoe::store::pack_compressed_model;
    let m = model(30);
    let mut rng = Rng::new(31);
    let cm = resmoe::compress::compress_model(&m, &ResMoE::up(), 0.25, 2, None, &mut rng);
    let dir = std::env::temp_dir().join("resmoe-integration-store");
    std::fs::create_dir_all(&dir).unwrap();
    let artifact = dir.join("serving.rmes");
    pack_compressed_model(&m, &cm.layers, 0.25, &artifact).unwrap();

    let budget = 2 * 32 * (2 * 16 + 1) * 4; // two dense experts' worth
    let packed = Engine::from_store(&artifact, budget).unwrap();
    let store = packed.backing_store().unwrap();
    assert!(
        (budget as u64) < store.total_expert_raw_bytes(),
        "budget must be smaller than total expert bytes for this test to bite"
    );
    // Startup loads backbone + skeletons only — no expert shard, nothing
    // near a full-file decompression.
    let startup_read = store.bytes_read();
    assert!(
        startup_read < store.file_bytes(),
        "construct-from-artifact must not read the whole file ({startup_read} of {})",
        store.file_bytes()
    );
    let mono = Engine::compressed(m.clone(), cm.layers.clone(), budget);
    let requests: Vec<Request> = (0..24)
        .map(|i| Request::Score {
            tokens: (0..10).map(|t| ((t * (i + 2) + 1) % 32) as u32).collect(),
        })
        .collect();
    let want: Vec<Response> = requests.iter().map(|r| mono.handle(r)).collect();

    let server = Server::start(
        packed.clone(),
        ServerConfig { batch_max: 4, batch_wait_us: 100, workers: 3, ..Default::default() },
    );
    let replies: Vec<_> = requests.iter().map(|r| server.submit(r.clone())).collect();
    for (rx, want) in replies.into_iter().zip(want) {
        let (got, _) = rx.recv().unwrap();
        match (got, want) {
            (Response::Score(a), Response::Score(b)) => {
                // Concurrent cache decisions may mix fused/restored serves,
                // so allow float-reassociation tolerance here (the serial
                // bit-identity check lives in the server unit tests).
                assert!((a - b).abs() < 1e-4, "{a} vs {b}")
            }
            other => panic!("{other:?}"),
        }
    }
    server.shutdown();
    packed.quiesce_prefetch();
    let cm2 = packed.cache_metrics().unwrap();
    assert!(cm2.shard_fetches > 0, "must have paged shards in");
    assert!(
        cm2.prefetch_hits + cm2.prefetch_misses > 0,
        "two compressed blocks must trigger next-block prefetch"
    );
}

#[test]
fn packed_concurrent_cold_start_is_bit_identical_with_roomy_budget() {
    // With an unbounded budget every miss decides restore (cost-model
    // rule 2) regardless of interleaving, and per-key singleflight hands
    // racing workers the same restored Arc — so even the cold-start
    // overlap is bit-identical to the serial answers, not merely within
    // float tolerance. Also pins the dedup guarantee: 2 blocks × 4
    // experts means at most 8 store fetches no matter how many workers
    // collide.
    use resmoe::store::pack_compressed_model;
    let m = model(40);
    let mut rng = Rng::new(41);
    let cm = resmoe::compress::compress_model(&m, &ResMoE::up(), 0.25, 2, None, &mut rng);
    let dir = std::env::temp_dir().join("resmoe-integration-store");
    std::fs::create_dir_all(&dir).unwrap();
    let artifact = dir.join("concurrent-bitident.rmes");
    pack_compressed_model(&m, &cm.layers, 0.25, &artifact).unwrap();
    let requests: Vec<Request> = (0..24)
        .map(|i| Request::Score {
            tokens: (0..10).map(|t| ((t * (i % 5 + 2) + 1) % 32) as u32).collect(),
        })
        .collect();
    // Serial ground truth from a second engine over the same artifact.
    let mut serial = Engine::from_store(&artifact, usize::MAX).unwrap();
    serial.disable_prefetch();
    let want: Vec<Response> = requests.iter().map(|r| serial.handle(r)).collect();
    let mut packed = Engine::from_store(&artifact, usize::MAX).unwrap();
    packed.disable_prefetch(); // strict fetch accounting below
    let server = Server::start(
        packed.clone(),
        ServerConfig { batch_max: 4, batch_wait_us: 100, workers: 4, ..Default::default() },
    );
    let replies: Vec<_> = requests.iter().map(|r| server.submit(r.clone())).collect();
    for (rx, want) in replies.into_iter().zip(want) {
        let (got, _) = rx.recv().unwrap();
        assert_eq!(got, want, "concurrent cold serving must be bit-identical");
    }
    server.shutdown();
    let cmx = packed.cache_metrics().unwrap();
    assert!(cmx.shard_fetches <= 8, "singleflight must dedup cold fetches: {cmx:?}");
    assert_eq!(cmx.restore_serves, cmx.misses, "roomy budget restores every miss");
}

#[test]
fn single_worker_batched_server_is_bit_identical_to_serial() {
    // With one worker, windows are contiguous admission-order slices of the
    // submission stream — and handle_batch(window) == serial handles, so
    // wherever the window boundaries fall the whole stream must equal the
    // serial reference EXACTLY (not within tolerance).
    let m = model(50);
    let mut rng = Rng::new(51);
    let cm = compress_model(&m, &ResMoE::up(), 0.25, 2, None, &mut rng);
    let requests: Vec<Request> = (0..20)
        .map(|i| Request::Score {
            tokens: (0..6 + i % 5).map(|t| ((t * (i + 2) + 1) % 32) as u32).collect(),
        })
        .collect();
    let serial = Engine::compressed(m.clone(), cm.layers.clone(), 1 << 20);
    let want: Vec<Response> = requests.iter().map(|r| serial.handle(r)).collect();
    let batched = Engine::compressed(m.clone(), cm.layers.clone(), 1 << 20);
    let server = Server::start(
        batched.clone(),
        ServerConfig { batch_max: 8, batch_wait_us: 2000, workers: 1, ..Default::default() },
    );
    let replies: Vec<_> = requests.iter().map(|r| server.submit(r.clone())).collect();
    for (rx, want) in replies.into_iter().zip(want) {
        let (got, _) = rx.recv().unwrap();
        assert_eq!(got, want, "batched serving must be bit-identical to serial");
    }
    server.shutdown();
    let bm = batched.batch_metrics();
    assert!(bm.windows > 0);
    assert_eq!(bm.batched_requests + bm.solo_requests, 20);
}

#[test]
fn batched_window_materializes_each_expert_at_most_once() {
    // Acceptance criterion: within one batch window every expert
    // materializes at most once — restores and store fetches are bounded
    // by the DISTINCT experts touched, not by window occupancy.
    use resmoe::store::pack_compressed_model;
    let m = model(60);
    let mut rng = Rng::new(61);
    let cm = resmoe::compress::compress_model(&m, &ResMoE::up(), 0.25, 2, None, &mut rng);
    let dir = std::env::temp_dir().join("resmoe-integration-store");
    std::fs::create_dir_all(&dir).unwrap();
    let artifact = dir.join("materialize-once.rmes");
    pack_compressed_model(&m, &cm.layers, 0.25, &artifact).unwrap();
    // 8 clients, overlapping token mixes → heavy expert sharing.
    let reqs: Vec<Request> = (0..8)
        .map(|i| Request::Score {
            tokens: (0..10).map(|t| ((t * (i % 3 + 2) + 1) % 32) as u32).collect(),
        })
        .collect();
    let mut engine = Engine::from_store(&artifact, usize::MAX).unwrap();
    engine.disable_prefetch();
    let responses = engine.handle_batch(&reqs);
    assert!(responses.iter().all(|r| matches!(r, Response::Score(_))), "{responses:?}");
    let cmx = engine.cache_metrics().unwrap();
    // 2 compressed blocks × 4 experts: no matter how many of the 8
    // requests demanded an expert, its shard was fetched and its dense
    // form restored at most once in the window.
    assert!(cmx.shard_fetches <= 8, "one fetch per distinct expert: {cmx:?}");
    assert!(cmx.restores_executed <= 8, "one restore per distinct expert: {cmx:?}");
    assert!(
        cmx.misses < cmx.hits + cmx.misses,
        "shared experts must hit after their first materialization: {cmx:?}"
    );
    let bm = engine.batch_metrics();
    assert_eq!(bm.windows, 1);
    assert_eq!(bm.batched_requests, 8);
    assert!(
        bm.mean_rows_per_dispatch() > 1.0,
        "cross-request rows must actually fuse: {bm:?}"
    );
}

#[test]
fn batching_amortizes_under_burst() {
    let m = model(10);
    let engine = compressed_engine(&m, usize::MAX, 11);
    let server = Server::start(
        engine,
        ServerConfig { batch_max: 8, batch_wait_us: 3000, workers: 1, ..Default::default() },
    );
    // Burst of 16 requests: with one worker and max batch 8, batches should
    // average well above 1.
    let replies: Vec<_> = (0..16)
        .map(|i| {
            server.submit(Request::Score {
                tokens: (0..8).map(|t| ((t + i) % 32) as u32).collect(),
            })
        })
        .collect();
    for r in replies {
        r.recv_timeout(Duration::from_secs(30)).unwrap();
    }
    let metrics = server.shutdown();
    assert!(
        metrics.mean_batch() > 1.5,
        "mean batch {:.2} — batching not engaging",
        metrics.mean_batch()
    );
}

#[test]
fn shutdown_drains_cleanly() {
    let m = model(12);
    let engine = Engine::dense(m);
    let server = Server::start(engine, ServerConfig::default());
    let rx = server.submit(Request::Score { tokens: vec![1, 2, 3, 4] });
    let metrics = server.shutdown();
    // The in-flight request completed before shutdown returned.
    assert!(rx.try_recv().is_ok());
    assert_eq!(metrics.requests, 1);
    assert!(metrics.wall_s > 0.0);
}
