//! SIMD-vs-scalar kernel properties (in-tree mini-prop harness).
//!
//! Two tiers of guarantees, matching README §Kernels:
//!
//! 1. **Numerical agreement** — the AVX2 kernels may legitimately differ
//!    from the scalar twins in final bits (FMA, lane-split reductions,
//!    polynomial exp), but must agree within `rel-err ≤ 1e-5` over random
//!    shapes INCLUDING ragged tails (rows/cols/batch not multiples of the
//!    6×16 GEMM tile or the 8-lane SpMM tile).
//! 2. **Row independence, bit-for-bit** — under whichever kernel
//!    `RESMOE_SIMD` resolved, an output row must be bitwise independent of
//!    the batch it rides in. This is the micro-theorem the serving parity
//!    suites (`prop_batched_serve_matches_serial_bit_for_bit`,
//!    `store_engine_matches_monolithic_engine_bit_for_bit`) rest on; CI
//!    runs the whole suite under both `RESMOE_SIMD` settings so those
//!    suites re-pin path-vs-path equality per kernel.

use resmoe::moe::{ExpertArch, MoeLayer};
use resmoe::tensor::kernel::{
    kernel_kind, matmul_into_with, matmul_nt_into_with, matmul_tn_with, KernelKind,
};
use resmoe::tensor::{sparse::IndexWidth, Csr, Matrix, QuantCsr, QuantMatrix};
use resmoe::util::prop::{check, gen, PropConfig};
use resmoe::Rng;

/// Naive f32 reference: C[i][j] = Σ_k A[i][k]·B[j][k], serial dot order.
fn naive_nt(a: &Matrix, bt: &Matrix) -> Matrix {
    assert_eq!(a.cols, bt.cols);
    Matrix::from_fn(a.rows, bt.rows, |i, j| {
        let mut acc = 0.0f32;
        for kk in 0..a.cols {
            acc += a.at(i, kk) * bt.at(j, kk);
        }
        acc
    })
}

fn rel_close(got: &Matrix, want: &Matrix, tol: f64) -> Result<(), String> {
    let denom = want.frob_norm_sq().max(1.0);
    let d = got.sq_dist(want);
    if d <= tol * tol * denom {
        Ok(())
    } else {
        Err(format!("rel dist {} over {:?}", (d / denom).sqrt(), want.shape()))
    }
}

fn both_kinds() -> Vec<KernelKind> {
    let mut kinds = vec![KernelKind::Scalar];
    if kernel_kind() != KernelKind::Scalar {
        kinds.push(kernel_kind());
    }
    kinds
}

#[test]
fn prop_gemm_kinds_agree_with_naive_over_ragged_shapes() {
    check(
        PropConfig { cases: 48, seed: 0x51D },
        |rng| {
            let m = gen::usize_in(rng, 1, 20);
            let n = gen::usize_in(rng, 1, 40);
            let k = gen::usize_in(rng, 1, 300);
            let a = Matrix::randn(m, k, 1.0, rng);
            let bt = Matrix::randn(n, k, 1.0, rng);
            (a, bt)
        },
        |(a, bt)| {
            let want = naive_nt(a, bt);
            let b = bt.transpose();
            for kind in both_kinds() {
                let mut nt = Matrix::zeros(a.rows, bt.rows);
                matmul_nt_into_with(kind, a, bt, &mut nt, false);
                rel_close(&nt, &want, 1e-5).map_err(|e| format!("{kind:?} NT: {e}"))?;
                let mut nn = Matrix::zeros(a.rows, bt.rows);
                matmul_into_with(kind, a, &b, &mut nn, false);
                rel_close(&nn, &want, 1e-5).map_err(|e| format!("{kind:?} NN: {e}"))?;
                let tn = matmul_tn_with(kind, &a.transpose(), &b);
                rel_close(&tn, &want, 1e-5).map_err(|e| format!("{kind:?} TN: {e}"))?;
            }
            // And the two kinds against each other (trivially true when only
            // one kind is available).
            let mut s = Matrix::zeros(a.rows, bt.rows);
            matmul_nt_into_with(KernelKind::Scalar, a, bt, &mut s, false);
            let mut v = Matrix::zeros(a.rows, bt.rows);
            matmul_nt_into_with(kernel_kind(), a, bt, &mut v, false);
            rel_close(&v, &s, 1e-5).map_err(|e| format!("scalar-vs-active: {e}"))
        },
    );
}

#[test]
fn prop_gemm_accumulate_matches_add_under_both_kinds() {
    check(
        PropConfig { cases: 24, seed: 0xACC },
        |rng| {
            let m = gen::usize_in(rng, 1, 13);
            let n = gen::usize_in(rng, 1, 33);
            let k = gen::usize_in(rng, 1, 64);
            (
                Matrix::randn(m, k, 1.0, rng),
                Matrix::randn(n, k, 1.0, rng),
                Matrix::randn(m, n, 1.0, rng),
            )
        },
        |(a, bt, seed)| {
            for kind in both_kinds() {
                let mut plain = Matrix::zeros(a.rows, bt.rows);
                matmul_nt_into_with(kind, a, bt, &mut plain, false);
                let mut acc = seed.clone();
                matmul_nt_into_with(kind, a, bt, &mut acc, true);
                // acc == seed + plain EXACTLY: the kernels compute the panel
                // sums identically and add them onto whatever C held.
                let want = seed.add(&plain);
                if acc != want {
                    return Err(format!("{kind:?}: accumulate != seed + plain"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_gemm_rows_are_batch_position_independent_bitwise() {
    // Core serving micro-theorem, under the ACTIVE kernel: concatenating
    // requests never changes any row's bits, for every split of the batch.
    check(
        PropConfig { cases: 32, seed: 0xB17 },
        |rng| {
            let total = gen::usize_in(rng, 2, 19);
            let split = gen::usize_in(rng, 1, total - 1);
            let n = gen::usize_in(rng, 1, 40);
            let k = gen::usize_in(rng, 1, 90);
            let x = Matrix::randn(total, k, 1.0, rng);
            let w = Matrix::randn(n, k, 1.0, rng);
            (x, w, split)
        },
        |(x, w, split)| {
            let xa = x.slice_rows(0, *split);
            let xb = x.slice_rows(*split, x.rows);
            let mut full = Matrix::zeros(x.rows, w.rows);
            matmul_nt_into_with(kernel_kind(), x, w, &mut full, false);
            let mut ya = Matrix::zeros(xa.rows, w.rows);
            matmul_nt_into_with(kernel_kind(), &xa, w, &mut ya, false);
            let mut yb = Matrix::zeros(xb.rows, w.rows);
            matmul_nt_into_with(kernel_kind(), &xb, w, &mut yb, false);
            if full.data != ya.vcat(&yb).data {
                return Err(format!(
                    "rows depend on batch position (split {split} of {})",
                    x.rows
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_csr_spmm_kinds_agree_and_rows_independent() {
    check(
        PropConfig { cases: 32, seed: 0xC54 },
        |rng| {
            let pi = gen::usize_in(rng, 1, 24);
            let p = gen::usize_in(rng, 1, 20);
            let b = gen::usize_in(rng, 1, 18);
            let density = [0.0, 0.05, 0.25, 0.5, 1.0][rng.below(5)];
            let delta = Matrix::from_fn(pi, p, |_, _| {
                if rng.uniform() < density {
                    rng.normal()
                } else {
                    0.0
                }
            });
            let x = Matrix::randn(b, p, 1.0, rng);
            let h = Matrix::randn(b, pi, 1.0, rng);
            (delta, x, h)
        },
        |(delta, x, h)| {
            let csr = Csr::from_dense(delta, IndexWidth::U16);
            let want_nt = naive_nt(x, delta);
            let want_acc = h.matmul(delta);
            for kind in both_kinds() {
                let mut nt = Matrix::zeros(x.rows, delta.rows);
                csr.matmul_nt_into_with(kind, x, &mut nt, false);
                rel_close(&nt, &want_nt, 1e-5).map_err(|e| format!("{kind:?} spmm_nt: {e}"))?;
                let mut acc = Matrix::zeros(h.rows, delta.cols);
                csr.matmul_acc_into_with(kind, h, &mut acc);
                rel_close(&acc, &want_acc, 1e-5).map_err(|e| format!("{kind:?} spmm_acc: {e}"))?;
            }
            // Bitwise row independence under the active kernel.
            if x.rows >= 2 {
                let split = x.rows / 2;
                let (xa, xb) = (x.slice_rows(0, split), x.slice_rows(split, x.rows));
                let mut full = Matrix::zeros(x.rows, delta.rows);
                csr.matmul_nt_into(x, &mut full, false);
                let mut ya = Matrix::zeros(xa.rows, delta.rows);
                csr.matmul_nt_into(&xa, &mut ya, false);
                let mut yb = Matrix::zeros(xb.rows, delta.rows);
                csr.matmul_nt_into(&xb, &mut yb, false);
                if full.data != ya.vcat(&yb).data {
                    return Err("spmm rows depend on batch position".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_moe_layer_forward_is_concat_invariant_under_active_kernel() {
    // The composed statement: routing + expert matmuls (dense kernels) +
    // activations (vexp tier) + weighted combine, over a row-concatenated
    // multi-request batch, equals each request's own forward EXACTLY —
    // whichever kernel this process resolved. This is the layer-level fact
    // the continuous-batching and store parity suites build on.
    check(
        PropConfig { cases: 16, seed: 0xCA7 },
        |rng| {
            let arch = if rng.below(2) == 0 { ExpertArch::Relu } else { ExpertArch::SwiGlu };
            let p = 4 + rng.below(8);
            let pi = 6 + rng.below(12);
            let n = 2 + rng.below(4);
            let top_k = 1 + rng.below(n.min(2));
            let layer = MoeLayer::random(arch, p, pi, n, top_k, rng.below(2) == 0, rng.below(2) == 0, rng);
            let ra = 1 + rng.below(6);
            let rb = 1 + rng.below(6);
            let xa = Matrix::randn(ra, p, 1.0, rng);
            let xb = Matrix::randn(rb, p, 1.0, rng);
            (layer, xa, xb)
        },
        |(layer, xa, xb)| {
            let cat = xa.vcat(xb);
            let y_cat = layer.forward(&cat, None);
            let ya = layer.forward(xa, None);
            let yb = layer.forward(xb, None);
            if y_cat.data != ya.vcat(&yb).data {
                return Err(format!(
                    "layer forward not concat-invariant under {:?}",
                    kernel_kind()
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_quant_dense_fused_bitwise_equals_dequant_then_gemm_per_kind() {
    // The int8 contract over random ragged shapes: each kernel kind's
    // dequant-fused GEMM is BITWISE equal to dequantizing first and running
    // that same kind's f32 GEMM (the fused kernels fold `(code as f32) ·
    // scale` into an identical FMA order) — and within rel-err of the
    // naive dequantized reference like every other kind.
    check(
        PropConfig { cases: 32, seed: 0x0178 },
        |rng| {
            let b = gen::usize_in(rng, 1, 14);
            let n = gen::usize_in(rng, 1, 40);
            let k = gen::usize_in(rng, 1, 300);
            let w = Matrix::randn(n, k, 1.0, rng);
            let x = Matrix::randn(b, k, 1.0, rng);
            let h = Matrix::randn(b, n, 1.0, rng);
            (w, x, h)
        },
        |(w, x, seed)| {
            let q = QuantMatrix::quantize(w);
            let dq = q.to_dense();
            // Per-element roundtrip error within the advertised bound.
            let bound = q.abs_error_bound();
            for (a, b) in w.data.iter().zip(&dq.data) {
                if (a - b).abs() > bound {
                    return Err(format!("roundtrip err {} > bound {bound}", (a - b).abs()));
                }
            }
            let want_naive = naive_nt(x, &dq);
            for kind in both_kinds() {
                let mut fused = Matrix::zeros(x.rows, w.rows);
                q.matmul_nt_into_with(kind, x, &mut fused, false);
                let mut two_step = Matrix::zeros(x.rows, w.rows);
                matmul_nt_into_with(kind, x, &dq, &mut two_step, false);
                if fused.data != two_step.data {
                    return Err(format!("{kind:?} NT: fused != dequant-then-GEMM"));
                }
                rel_close(&fused, &want_naive, 1e-5)
                    .map_err(|e| format!("{kind:?} NT vs naive: {e}"))?;
                // Accumulating form onto a random seed.
                let mut facc = seed.clone();
                q.matmul_nt_into_with(kind, x, &mut facc, true);
                let mut wacc = seed.clone();
                matmul_nt_into_with(kind, x, &dq, &mut wacc, true);
                if facc.data != wacc.data {
                    return Err(format!("{kind:?} NT-acc: fused != dequant-then-GEMM"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_quant_csr_fused_bitwise_equals_dequant_then_spmm_per_kind() {
    check(
        PropConfig { cases: 32, seed: 0x0179 },
        |rng| {
            let pi = gen::usize_in(rng, 1, 24);
            let p = gen::usize_in(rng, 1, 20);
            let b = gen::usize_in(rng, 1, 14);
            let density = [0.0, 0.05, 0.25, 1.0][rng.below(4)];
            let delta = Matrix::from_fn(pi, p, |_, _| {
                if rng.uniform() < density {
                    rng.normal()
                } else {
                    0.0
                }
            });
            let x = Matrix::randn(b, p, 1.0, rng);
            let h = Matrix::randn(b, pi, 1.0, rng);
            (delta, x, h)
        },
        |(delta, x, h)| {
            let csr = Csr::from_dense(delta, IndexWidth::U16);
            let q = QuantCsr::quantize(&csr);
            let dq = q.to_csr();
            // The quantized CSR keeps the sparsity pattern bit-for-bit.
            if dq.row_ptr != csr.row_ptr || dq.col_idx != csr.col_idx {
                return Err("quantized CSR changed the sparsity pattern".into());
            }
            let want_naive = naive_nt(x, &dq.to_dense());
            for kind in both_kinds() {
                let mut fused = Matrix::zeros(x.rows, delta.rows);
                q.matmul_nt_into_with(kind, x, &mut fused, false);
                let mut two_step = Matrix::zeros(x.rows, delta.rows);
                dq.matmul_nt_into_with(kind, x, &mut two_step, false);
                if fused.data != two_step.data {
                    return Err(format!("{kind:?} spmm_nt: fused != dequant-then-SpMM"));
                }
                rel_close(&fused, &want_naive, 1e-5)
                    .map_err(|e| format!("{kind:?} spmm_nt vs naive: {e}"))?;
                let mut facc = Matrix::zeros(h.rows, delta.cols);
                q.matmul_acc_into_with(kind, h, &mut facc);
                let mut wacc = Matrix::zeros(h.rows, delta.cols);
                dq.matmul_acc_into_with(kind, h, &mut wacc);
                if facc.data != wacc.data {
                    return Err(format!("{kind:?} spmm_acc: fused != dequant-then-SpMM"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_elementwise_tier_agrees_with_scalar_reference() {
    check(
        PropConfig { cases: 32, seed: 0xE1E },
        |rng| {
            let n = gen::usize_in(rng, 1, 70);
            let xs = gen::f32_vec(rng, n, 3.0);
            let gain = gen::nonzero_f32_vec(rng, n, 1.0);
            (xs, gain)
        },
        |(xs, gain)| {
            // softmax: dispatched vs pure-scalar reference.
            let got = resmoe::util::stats::softmax(xs);
            let max = xs.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let exps: Vec<f32> = xs.iter().map(|x| (x - max).exp()).collect();
            let sum: f32 = exps.iter().sum();
            for (g, e) in got.iter().zip(&exps) {
                let want = e / sum;
                if (g - want).abs() > 1e-5 * want.abs().max(1e-6) {
                    return Err(format!("softmax: {g} vs {want}"));
                }
            }
            // rmsnorm row.
            let mut out = vec![0.0f32; xs.len()];
            resmoe::moe::transformer::rmsnorm(xs, gain, &mut out);
            let ms: f32 = xs.iter().map(|v| v * v).sum::<f32>() / xs.len() as f32;
            let inv = 1.0 / (ms + 1e-6).sqrt();
            for ((o, &v), &g) in out.iter().zip(xs).zip(gain) {
                let want = v * inv * g;
                if (o - want).abs() > 1e-5 * want.abs().max(1e-5) {
                    return Err(format!("rmsnorm: {o} vs {want}"));
                }
            }
            // silu·gate over a matrix row (the SwiGLU combine).
            let mut h = Matrix::from_vec(1, xs.len(), xs.clone());
            let g = Matrix::from_vec(1, gain.len(), gain.clone());
            resmoe::tensor::kernel::silu_mul(&mut h, &g);
            for (c, (&x, &gv)) in xs.iter().zip(gain.iter()).enumerate() {
                let want = resmoe::tensor::kernel::silu(x) * gv;
                let got = h.at(0, c);
                if (got - want).abs() > 1e-5 * want.abs().max(1e-5) {
                    return Err(format!("silu_mul col {c}: {got} vs {want}"));
                }
            }
            Ok(())
        },
    );
}
