//! Property-based invariants (in-tree mini-prop harness; proptest is not in
//! the offline vendor set). Focus: coordinator state invariants, compression
//! restoration identities, and OT solver optimality — the "L3 proptest on
//! routing/batching/state" requirement.

use resmoe::baselines::quick_compress;
use resmoe::compress::{CompressCtx, Compressor, ResMoE};
use resmoe::coordinator::ExpertCache;
use resmoe::eval::{method_by_name, ALL_METHODS};
use resmoe::moe::{ExpertArch, ExpertWeights, MoeLayer};
use resmoe::ot::{cost::sq_euclidean, hungarian, wasserstein2_sq};
use resmoe::tensor::Matrix;
use resmoe::util::prop::{check, gen, PropConfig};
use resmoe::Rng;

fn random_layer(rng: &mut Rng) -> MoeLayer {
    let arch = if rng.below(2) == 0 { ExpertArch::Relu } else { ExpertArch::SwiGlu };
    let p = 4 + rng.below(8);
    let pi = 6 + rng.below(12);
    let n = 2 + rng.below(4);
    let top_k = 1 + rng.below(n.min(2));
    let upcycled = rng.below(2) == 0;
    MoeLayer::random(arch, p, pi, n, top_k, upcycled, false, rng)
}

#[test]
fn prop_restored_layers_are_function_preserving_at_full_rate() {
    // At rate 1.0 the ResMoE pipeline is exact restoration (Prop 4.1 +
    // permutation invariance): outputs match to float tolerance for ANY
    // random layer geometry.
    check(
        PropConfig { cases: 24, seed: 0xA11CE },
        |rng| {
            let layer = random_layer(rng);
            let x = Matrix::randn(5, layer.experts[0].d_model(), 1.0, rng);
            (layer, x)
        },
        |(layer, x)| {
            let cl = quick_compress(&ResMoE::up(), layer, 1.0, 1);
            let restored = cl.to_layer(layer);
            let d = layer.forward(x, None).sq_dist(&restored.forward(x, None));
            if d < 1e-6 {
                Ok(())
            } else {
                Err(format!("function not preserved: sq dist {d}"))
            }
        },
    );
}

#[test]
fn prop_every_method_respects_monotone_error_in_rate() {
    check(
        PropConfig { cases: 10, seed: 0xB0B },
        |rng| (random_layer(rng), ["resmoe-up", "up-concat", "svd-concat"][rng.below(3)]),
        |(layer, method)| {
            let comp = method_by_name(method).unwrap();
            let lo = quick_compress(comp.as_ref(), layer, 0.15, 3).approx_error(layer);
            let hi = quick_compress(comp.as_ref(), layer, 0.6, 3).approx_error(layer);
            if hi <= lo + 1e-9 {
                Ok(())
            } else {
                Err(format!("{method}: error not monotone ({lo} -> {hi})"))
            }
        },
    );
}

#[test]
fn prop_expert_map_and_aligns_are_well_formed() {
    check(
        PropConfig { cases: 20, seed: 0xC0DE },
        |rng| {
            let layer = random_layer(rng);
            let name = ALL_METHODS[rng.below(ALL_METHODS.len())];
            let seed = rng.next_u64();
            (layer, name, seed)
        },
        |(layer, name, seed)| {
            let comp = method_by_name(name).unwrap();
            let mut rng = Rng::new(*seed);
            let mut ctx = CompressCtx::new(0.3, &mut rng);
            let calib = Matrix::randn(8, layer.experts[0].d_model(), 1.0, &mut Rng::new(1));
            ctx.calib = Some(&calib);
            let cl = comp.compress(layer, &mut ctx);
            let n = layer.n_experts();
            let pi = layer.experts[0].d_inner();
            if cl.expert_map.len() != n {
                return Err(format!("{name}: map len {}", cl.expert_map.len()));
            }
            if cl.expert_map.iter().any(|&m| m >= cl.experts.len()) {
                return Err(format!("{name}: map out of range"));
            }
            if cl.aligns.len() != n {
                return Err(format!("{name}: aligns len {}", cl.aligns.len()));
            }
            for a in &cl.aligns {
                let mut s = a.clone();
                s.sort_unstable();
                if s != (0..pi).collect::<Vec<_>>() {
                    return Err(format!("{name}: align not a permutation"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_fused_forward_matches_restore_then_dense() {
    // The tentpole equivalence: for BOTH residual kinds (UP sparse / SVD
    // low-rank), scoring straight from the compressed representation must
    // match restore-then-dense within 1e-4 for any layer geometry and batch
    // size — including rate extremes that produce empty and (near-)dense
    // residuals.
    check(
        PropConfig { cases: 24, seed: 0xF05ED },
        |rng| {
            let layer = random_layer(rng);
            let svd = rng.below(2) == 1;
            let rate = [0.0, 0.15, 0.4, 1.0][rng.below(4)];
            let batch = 1 + rng.below(7);
            let seed = rng.next_u64();
            (layer, svd, rate, batch, seed)
        },
        |(layer, svd, rate, batch, seed)| {
            let comp = if *svd { ResMoE::svd() } else { ResMoE::up() };
            let cl = quick_compress(&comp, layer, *rate, *seed);
            let Some(fl) = cl.fused() else {
                return Err("resmoe layer must expose a fused path".into());
            };
            let mut rng = Rng::new(seed ^ 0x9E3779B97F4A7C15);
            let x = Matrix::randn(*batch, layer.experts[0].d_model(), 1.0, &mut rng);
            let shared = fl.shared_act(&x);
            for slot in 0..layer.n_experts() {
                let want = cl.restore_expert(slot).forward(&x);
                let got = fl.forward_slot(slot, &x, &shared);
                let dist = got.sq_dist(&want).sqrt();
                let tol = 1e-4 * (1.0 + want.frob_norm());
                if dist > tol {
                    return Err(format!(
                        "slot {slot} ({}, rate {rate}): |fused - restored| = {dist:.3e} > {tol:.3e}",
                        cl.method
                    ));
                }
            }
            // The convenience entry agrees with the shared-act path.
            let via = cl.fused_forward(0, &x).expect("fused path exists");
            if via.sq_dist(&fl.forward_slot(0, &x, &shared)) > 1e-10 {
                return Err("fused_forward disagrees with forward_slot".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_serve_decisions_are_consistent_and_correct() {
    // Random access sequences under random budgets through the cost-model
    // serve path: every answer (dense or fused) must equal direct
    // restoration, and the decision metrics must account for every miss.
    use resmoe::coordinator::Serve;
    check(
        PropConfig { cases: 12, seed: 0x5E4E },
        |rng| {
            let layer = random_layer(rng);
            let seed = rng.next_u64();
            let ops: Vec<usize> = (0..24).map(|_| rng.below(layer.n_experts())).collect();
            let budget_experts = rng.below(3); // 0 = pure thrash
            let batch = 1 + rng.below(5);
            (layer, seed, ops, budget_experts, batch)
        },
        |(layer, seed, ops, budget_experts, batch)| {
            let cl = quick_compress(&ResMoE::up(), layer, 0.3, *seed);
            let expert_bytes = layer.experts[0].n_params() * 4;
            let budget = budget_experts * expert_bytes;
            let cache = ExpertCache::new(vec![(0, cl.clone())], budget);
            let mut rng = Rng::new(*seed);
            let x = Matrix::randn(*batch, layer.experts[0].d_model(), 1.0, &mut rng);
            for &slot in ops {
                let want = cl.restore_expert(slot).forward(&x);
                let got = match cache.try_serve(0, slot, x.rows).expect("monolithic never fails") {
                    Serve::Dense(e) => e.forward(&x),
                    Serve::Fused(fl) => {
                        let sh = fl.shared_act(&x);
                        fl.forward_slot(slot, &x, &sh)
                    }
                    Serve::Paged { .. } => {
                        return Err("monolithic cache must never serve paged".into())
                    }
                    Serve::Degraded(_) => {
                        return Err("monolithic cache must never degrade".into())
                    }
                };
                let tol = 1e-4 * (1.0 + want.frob_norm());
                if got.sq_dist(&want).sqrt() > tol {
                    return Err(format!("slot {slot}: serve output diverged"));
                }
            }
            let m = cache.metrics();
            if m.hits + m.misses != ops.len() as u64 {
                return Err("hit+miss accounting broken".into());
            }
            if m.restore_serves + m.fused_serves != m.misses {
                return Err(format!(
                    "every miss needs a recorded decision: {} + {} != {}",
                    m.restore_serves, m.fused_serves, m.misses
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cache_never_exceeds_budget_and_stays_correct() {
    // Random access sequences under random budgets: the cache's used bytes
    // never exceed budget (except a single over-budget entry), and every
    // returned expert equals direct restoration.
    check(
        PropConfig { cases: 16, seed: 0xCAFE },
        |rng| {
            let layer = random_layer(rng);
            let seed = rng.next_u64();
            let ops: Vec<usize> = (0..30).map(|_| rng.below(layer.n_experts())).collect();
            let budget_experts = 1 + rng.below(3);
            (layer, seed, ops, budget_experts)
        },
        |(layer, seed, ops, budget_experts)| {
            let cl = quick_compress(&ResMoE::up(), layer, 0.3, *seed);
            let expert_bytes = layer.experts[0].n_params() * 4;
            let budget = budget_experts * expert_bytes;
            let cache = ExpertCache::new(vec![(0, cl.clone())], budget);
            for &slot in ops {
                let got = cache.try_get(0, slot).expect("monolithic restore never fails");
                let want = cl.restore_expert(slot);
                if *got != want {
                    return Err(format!("slot {slot}: cached expert differs"));
                }
                if cache.resident_experts() > 1 && cache.used_bytes() > budget {
                    return Err(format!(
                        "over budget: {} > {budget} with {} resident",
                        cache.used_bytes(),
                        cache.resident_experts()
                    ));
                }
            }
            let m = cache.metrics();
            if m.hits + m.misses != ops.len() as u64 {
                return Err("hit+miss accounting broken".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_concurrent_cold_misses_singleflight_and_match_serial_serve() {
    // The concurrent-serving-core guarantee: N workers cold-missing the
    // SAME expert of a store-backed cache trigger exactly ONE store fetch
    // (per-key singleflight), and every worker's forward output is
    // bit-identical to a serial reference serve of the same request.
    use resmoe::coordinator::Serve;
    use resmoe::moe::{Model, ModelConfig};
    use resmoe::store::{pack_compressed_model, ExpertStore};
    use std::sync::{Arc, Barrier};
    let dir = std::env::temp_dir().join("resmoe-prop-singleflight");
    std::fs::create_dir_all(&dir).unwrap();
    check(
        PropConfig { cases: 6, seed: 0x51F117 },
        |rng| {
            let layer = random_layer(rng);
            let seed = rng.next_u64();
            let slot = rng.below(layer.n_experts());
            let threads = 2 + rng.below(7);
            (layer, seed, slot, threads)
        },
        |(layer, seed, slot, threads)| {
            let cl = quick_compress(&ResMoE::up(), layer, 0.3, *seed);
            let p = layer.experts[0].d_model();
            let mut cfg = ModelConfig::switch_mini(layer.n_experts());
            cfg.d_model = p;
            cfg.d_inner = layer.experts[0].d_inner();
            cfg.n_layers = 2;
            cfg.n_heads = 1;
            cfg.vocab_size = 32;
            cfg.max_seq = 16;
            let mut mrng = Rng::new(*seed);
            let model = Model::random(&cfg, &mut mrng);
            let path = dir.join(format!("sf-{seed}.rmes"));
            pack_compressed_model(&model, &[(1, cl.clone())], 0.3, &path)
                .map_err(|e| format!("pack failed: {e:#}"))?;
            let store =
                Arc::new(ExpertStore::open(&path).map_err(|e| format!("open failed: {e:#}"))?);
            let mut xrng = Rng::new(*seed ^ 1);
            let x = Matrix::randn(3, p, 1.0, &mut xrng);
            // Serial reference: one serve on a fresh cache. Batch 4096
            // forces the restore decision (cost-model rule 1) so the
            // concurrent run below decides identically from any state.
            let serial = ExpertCache::from_store(store.clone(), usize::MAX)
                .map_err(|e| format!("{e:#}"))?;
            let want = match serial.try_serve(1, *slot, 4096).map_err(|e| format!("{e:#}"))? {
                Serve::Dense(e) => e.forward(&x),
                _ => return Err("batch 4096 must restore".into()),
            };
            if serial.metrics().shard_fetches != 1 {
                return Err("serial reference must fetch exactly once".into());
            }
            // Concurrent: N threads race the same cold key.
            let cache = Arc::new(
                ExpertCache::from_store(store.clone(), usize::MAX)
                    .map_err(|e| format!("{e:#}"))?,
            );
            let barrier = Barrier::new(*threads);
            let outs: Vec<Result<Matrix, String>> = std::thread::scope(|s| {
                let handles: Vec<_> = (0..*threads)
                    .map(|_| {
                        let cache = &cache;
                        let barrier = &barrier;
                        let x = &x;
                        s.spawn(move || {
                            barrier.wait();
                            match cache.try_serve(1, *slot, 4096) {
                                Ok(Serve::Dense(e)) => Ok(e.forward(x)),
                                Ok(_) => Err("must restore".to_string()),
                                Err(e) => Err(format!("{e:#}")),
                            }
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            std::fs::remove_file(&path).ok();
            for out in outs {
                let out = out?;
                if out.data != want.data {
                    return Err("concurrent serve diverged from serial reference".into());
                }
            }
            let m = cache.metrics();
            if m.shard_fetches != 1 {
                return Err(format!("singleflight broken: {} store fetches", m.shard_fetches));
            }
            if m.hits + m.misses != *threads as u64 {
                return Err("every thread's serve must be accounted".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_store_pack_load_roundtrips_bit_exactly() {
    // Any compressed layer (UP and SVD residuals, including the rate 0 and
    // rate 1 edges) written to an RMES artifact loads back EQUAL to the
    // in-memory CompressedLayer — bit-exact f32s, map, aligns and all.
    use resmoe::moe::{Model, ModelConfig};
    use resmoe::store::{pack_compressed_model, ExpertStore};
    let dir = std::env::temp_dir().join("resmoe-prop-store");
    std::fs::create_dir_all(&dir).unwrap();
    check(
        PropConfig { cases: 12, seed: 0x5708E },
        |rng| {
            let layer = random_layer(rng);
            let seed = rng.next_u64();
            let rate = [0.0, 1.0, rng.uniform()][rng.below(3)];
            let svd = rng.below(2) == 1;
            (layer, seed, rate, svd)
        },
        |(layer, seed, rate, svd)| {
            let comp = if *svd { ResMoE::svd() } else { ResMoE::up() };
            let cl = quick_compress(&comp, layer, *rate, *seed);
            let mut cfg = ModelConfig::switch_mini(4);
            cfg.d_model = 8;
            cfg.d_inner = 16;
            cfg.n_layers = 2;
            cfg.n_heads = 2;
            cfg.vocab_size = 32;
            cfg.max_seq = 16;
            let mut mrng = Rng::new(*seed);
            let model = Model::random(&cfg, &mut mrng);
            let path = dir.join(format!("rt-{seed}-{svd}.rmes"));
            pack_compressed_model(&model, &[(1, cl.clone())], *rate, &path)
                .map_err(|e| format!("pack failed: {e:#}"))?;
            let store = ExpertStore::open(&path).map_err(|e| format!("open failed: {e:#}"))?;
            let loaded = store
                .load_layer_full(1)
                .map_err(|e| format!("load failed: {e:#}"))?;
            std::fs::remove_file(&path).ok();
            if loaded != cl {
                return Err(format!(
                    "pack→load changed the layer (method {}, rate {rate})",
                    cl.method
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_quantized_pack_load_restores_within_advertised_bound() {
    // The int8 tier end-to-end: quantize → pack → load returns the
    // quantized layer BIT-exact (codes, scales, `qerr` index field), and
    // its restoration stays within each expert's advertised per-element
    // error bound of the f32 original — at the rate edges {0, 1} and the
    // paper's 0.25, for sparse (UP) and low-rank (SVD) residuals alike.
    use resmoe::moe::{Model, ModelConfig};
    use resmoe::store::{pack_compressed_model, quantize_layer, ExpertStore};
    let dir = std::env::temp_dir().join("resmoe-prop-store");
    std::fs::create_dir_all(&dir).unwrap();
    check(
        PropConfig { cases: 12, seed: 0x0178BD },
        |rng| {
            let layer = random_layer(rng);
            let seed = rng.next_u64();
            let rate = [0.0, 0.25, 1.0][rng.below(3)];
            let svd = rng.below(2) == 1;
            (layer, seed, rate, svd)
        },
        |(layer, seed, rate, svd)| {
            let comp = if *svd { ResMoE::svd() } else { ResMoE::up() };
            let cl = quick_compress(&comp, layer, *rate, *seed);
            let clq = quantize_layer(&cl);
            let mut cfg = ModelConfig::switch_mini(layer.n_experts());
            cfg.d_model = layer.experts[0].d_model();
            cfg.d_inner = layer.experts[0].d_inner();
            cfg.n_layers = 2;
            cfg.n_heads = 1;
            cfg.vocab_size = 32;
            cfg.max_seq = 16;
            let mut mrng = Rng::new(*seed);
            let model = Model::random(&cfg, &mut mrng);
            let path = dir.join(format!("qrt-{seed}-{svd}.rmes"));
            pack_compressed_model(&model, &[(1, clq.clone())], *rate, &path)
                .map_err(|e| format!("pack failed: {e:#}"))?;
            let store = ExpertStore::open(&path).map_err(|e| format!("open failed: {e:#}"))?;
            let loaded = store
                .load_layer_full(1)
                .map_err(|e| format!("load failed: {e:#}"))?;
            let entry = store.layer_entry(1).expect("layer stored").clone();
            std::fs::remove_file(&path).ok();
            if loaded != clq {
                return Err(format!("quantized pack→load changed the layer (rate {rate})"));
            }
            // Every shard landed in the int8 tier and advertises its bound.
            for (i, e) in clq.experts.iter().enumerate() {
                if !entry.experts[i].kind.starts_with("q8-") {
                    return Err(format!("expert {i} kind {}", entry.experts[i].kind));
                }
                let adv = entry.experts[i].quant_err;
                let bound = e.quant_error_bound();
                if (adv - bound).abs() > 1e-6 * bound.abs().max(1e-12) {
                    return Err(format!("expert {i}: qerr {adv} != bound {bound}"));
                }
            }
            // Restoration error vs the f32 original obeys the bound: the
            // residual is the only perturbed term of `center + residual`.
            for slot in 0..layer.n_experts() {
                let want = cl.restore_expert(slot);
                let got = clq.restore_expert(slot);
                if got.b2 != want.b2 {
                    return Err(format!("slot {slot}: b2 must stay exact f32"));
                }
                let wd = want.design_matrix();
                let gd = got.design_matrix();
                let k = cl.expert_map[slot];
                let bound = clq.experts[k].quant_error_bound() + 1e-5;
                let mut worst = 0.0f32;
                for (a, b) in wd.data.iter().zip(&gd.data) {
                    worst = worst.max((a - b).abs());
                }
                if worst > bound {
                    return Err(format!(
                        "slot {slot} (svd={svd}, rate {rate}): err {worst} > bound {bound}"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_store_detects_any_single_bit_flip_in_expert_shards() {
    // Flip one random bit anywhere inside a random expert's shard bytes:
    // loading that expert must fail (CRC-32 catches every 1-bit error) and
    // must NEVER silently return data. Truncating the file must fail open.
    use resmoe::moe::{Model, ModelConfig};
    use resmoe::store::{pack_compressed_model, ExpertStore};
    let dir = std::env::temp_dir().join("resmoe-prop-store");
    std::fs::create_dir_all(&dir).unwrap();
    check(
        PropConfig { cases: 10, seed: 0xB17F11 },
        |rng| {
            let layer = random_layer(rng);
            let seed = rng.next_u64();
            (layer, seed, rng.uniform(), rng.uniform(), rng.uniform())
        },
        |(layer, seed, expert_pick, byte_pick, bit_pick)| {
            let cl = quick_compress(&ResMoE::up(), layer, 0.4, *seed);
            let mut cfg = ModelConfig::switch_mini(4);
            cfg.d_model = 8;
            cfg.d_inner = 16;
            cfg.n_layers = 2;
            cfg.n_heads = 2;
            cfg.vocab_size = 32;
            cfg.max_seq = 16;
            let mut mrng = Rng::new(*seed);
            let model = Model::random(&cfg, &mut mrng);
            let path = dir.join(format!("flip-{seed}.rmes"));
            pack_compressed_model(&model, &[(1, cl.clone())], 0.4, &path)
                .map_err(|e| format!("pack failed: {e:#}"))?;
            let (info, eidx) = {
                let store =
                    ExpertStore::open(&path).map_err(|e| format!("open failed: {e:#}"))?;
                let entry = store.layer_entry(1).expect("layer stored");
                let eidx =
                    (*expert_pick * entry.experts.len() as f64) as usize % entry.experts.len();
                (entry.experts[eidx].shard.clone(), eidx)
            };
            let mut bytes = std::fs::read(&path).unwrap();
            let pos = info.offset as usize + (*byte_pick * info.bytes as f64) as usize;
            let pos = pos.min(info.offset as usize + info.bytes as usize - 1);
            let bit = ((*bit_pick * 8.0) as u32).min(7);
            bytes[pos] ^= 1 << bit;
            std::fs::write(&path, &bytes).unwrap();
            let store =
                ExpertStore::open(&path).map_err(|e| format!("reopen failed: {e:#}"))?;
            let corrupt = store.load_expert(1, eidx);
            let verdict = match corrupt {
                Ok(_) => Err(format!(
                    "bit flip at {pos}:{bit} in expert {eidx} served silently"
                )),
                Err(_) => Ok(()),
            };
            drop(store);
            // Truncation: cut the file somewhere after the header.
            let cut = 16 + (*byte_pick * (bytes.len() - 17) as f64) as usize;
            std::fs::write(&path, &bytes[..cut]).unwrap();
            if ExpertStore::open(&path).is_ok() {
                // Opening may legitimately succeed if the cut only removed
                // trailing index bytes... it cannot: the index is last and
                // parsing requires it whole. Any Ok here is a bug.
                std::fs::remove_file(&path).ok();
                return Err(format!("truncated artifact (cut {cut}) opened cleanly"));
            }
            std::fs::remove_file(&path).ok();
            verdict
        },
    );
}

#[test]
fn prop_hungarian_beats_random_permutations() {
    check(
        PropConfig { cases: 30, seed: 0xD1CE },
        |rng| {
            let n = 2 + rng.below(10);
            let cost = Matrix::from_fn(n, n, |_, _| rng.uniform() as f32 * 5.0);
            let probe = rng.permutation(n);
            (cost, probe)
        },
        |(cost, probe)| {
            let opt = hungarian::solve(cost);
            let probe_cost: f64 = probe
                .iter()
                .enumerate()
                .map(|(i, &j)| cost.at(i, j) as f64)
                .sum();
            if opt.cost <= probe_cost + 1e-6 {
                Ok(())
            } else {
                Err(format!("assignment {:.4} worse than random {probe_cost:.4}", opt.cost))
            }
        },
    );
}

#[test]
fn prop_w2_is_a_metric_on_point_clouds() {
    // Symmetry + triangle inequality (sqrt of W2²) on small clouds.
    check(
        PropConfig { cases: 20, seed: 0xE7C },
        |rng| {
            let n = 3 + rng.below(6);
            let d = 2 + rng.below(4);
            (
                Matrix::randn(n, d, 1.0, rng),
                Matrix::randn(n, d, 1.0, rng),
                Matrix::randn(n, d, 1.0, rng),
            )
        },
        |(a, b, c)| {
            let dab = wasserstein2_sq(a, b).sqrt();
            let dba = wasserstein2_sq(b, a).sqrt();
            if (dab - dba).abs() > 1e-5 {
                return Err(format!("not symmetric: {dab} vs {dba}"));
            }
            let dac = wasserstein2_sq(a, c).sqrt();
            let dcb = wasserstein2_sq(c, b).sqrt();
            if dab > dac + dcb + 1e-5 {
                return Err(format!("triangle violated: {dab} > {dac} + {dcb}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_design_matrix_roundtrip_any_geometry() {
    check(
        PropConfig { cases: 30, seed: 0xF00D },
        |rng| {
            let arch = if rng.below(2) == 0 { ExpertArch::Relu } else { ExpertArch::SwiGlu };
            let p = 1 + rng.below(12);
            let pi = 1 + rng.below(16);
            let seed = rng.next_u64();
            (arch, p, pi, seed)
        },
        |&(arch, p, pi, seed)| {
            let mut rng = Rng::new(seed);
            let e = ExpertWeights::random(arch, p, pi, &mut rng);
            let back =
                ExpertWeights::from_design_matrix(arch, p, &e.design_matrix(), e.b2.clone());
            if back == e {
                Ok(())
            } else {
                Err("roundtrip mismatch".into())
            }
        },
    );
}

#[test]
fn prop_barycenter_alignment_cost_equals_w2_alignment() {
    // For any two clouds, the Hungarian alignment cost on the sq-euclidean
    // matrix equals n·W2² (the Prop 4.1 bridge).
    check(
        PropConfig { cases: 20, seed: 0xABCD },
        |rng| {
            let n = 3 + rng.below(8);
            let d = 2 + rng.below(5);
            (Matrix::randn(n, d, 1.0, rng), Matrix::randn(n, d, 1.0, rng))
        },
        |(a, b)| {
            let direct = hungarian::solve(&sq_euclidean(a, b)).cost;
            let via_w2 = wasserstein2_sq(a, b) * a.rows as f64;
            if (direct - via_w2).abs() < 1e-6 * direct.max(1.0) {
                Ok(())
            } else {
                Err(format!("{direct} vs {via_w2}"))
            }
        },
    );
}

#[test]
fn prop_generators_are_seed_deterministic() {
    check(
        PropConfig { cases: 10, seed: 0x5EED },
        |rng| rng.next_u64(),
        |&seed| {
            let a = gen::f32_vec(&mut Rng::new(seed), 32, 1.0);
            let b = gen::f32_vec(&mut Rng::new(seed), 32, 1.0);
            if a == b {
                Ok(())
            } else {
                Err("generator not deterministic".into())
            }
        },
    );
}
