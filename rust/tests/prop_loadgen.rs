//! Loadgen determinism properties (ISSUE 9 S4): a fixed `(scenario,
//! seed)` pair must replay **bit-identically** — same schedule, same
//! response stream, same counter snapshot — across repeated runs and
//! across virtual worker counts {1, 4}; different seeds must produce
//! different schedules; sheds happen only where the scenario intends
//! them; and no scenario ever surfaces a `Response::Error`.

use resmoe::compress::{compress_model, ResMoE};
use resmoe::coordinator::Engine;
use resmoe::loadgen::{self, Fleet, Scenario, CLASSIFY_TASK};
use resmoe::moe::{Model, ModelConfig};
use resmoe::Matrix;
use resmoe::Rng;

fn model(seed: u64) -> Model {
    let mut cfg = ModelConfig::switch_mini(4);
    cfg.d_model = 16;
    cfg.d_inner = 32;
    cfg.n_layers = 2;
    cfg.n_heads = 2;
    cfg.vocab_size = 32;
    cfg.max_seq = 32;
    let mut rng = Rng::new(seed);
    let mut m = Model::random(&cfg, &mut rng);
    m.heads.push((
        CLASSIFY_TASK.to_string(),
        Matrix::randn(3, m.cfg.d_model, 0.2, &mut rng),
    ));
    m
}

fn fleet(tenants: usize, budget: usize) -> Fleet {
    Fleet::from_engines(
        (0..tenants)
            .map(|_| {
                let m = model(17);
                let mut rng = Rng::new(0x10ad);
                let cm = compress_model(&m, &ResMoE::up(), 0.25, 2, None, &mut rng);
                Engine::compressed(m, cm.layers, budget)
            })
            .collect(),
    )
}

#[test]
fn fixed_seed_replays_bit_identically_across_runs_and_worker_counts() {
    for sc in Scenario::canned() {
        // Fresh fleet per run: counters must start from zero both times.
        let a = loadgen::run_scenario(&fleet(sc.tenants, 48 * 1024), &sc, 7, 4).unwrap();
        let b = loadgen::run_scenario(&fleet(sc.tenants, 48 * 1024), &sc, 7, 1).unwrap();
        assert_eq!(
            a.schedule_fp, b.schedule_fp,
            "{}: schedule must be seed-deterministic",
            sc.name
        );
        assert_eq!(
            a.responses_fp, b.responses_fp,
            "{}: responses must replay bit-identically (vworkers 4 vs 1)",
            sc.name
        );
        assert_eq!(
            a.counters_fp, b.counters_fp,
            "{}: counter snapshots must replay bit-identically",
            sc.name
        );
    }
}

#[test]
fn different_seeds_produce_different_schedules() {
    for sc in Scenario::canned() {
        let a = loadgen::generate(&sc, 7);
        let b = loadgen::generate(&sc, 8);
        assert_ne!(
            loadgen::schedule_fingerprint(&a),
            loadgen::schedule_fingerprint(&b),
            "{}: seed must matter",
            sc.name
        );
    }
}

#[test]
fn every_scenario_conserves_requests_and_never_errors() {
    for sc in Scenario::canned() {
        let run = loadgen::run_scenario(&fleet(sc.tenants, 48 * 1024), &sc, 7, 4).unwrap();
        assert_eq!(run.arrivals, sc.requests as u64, "{}", sc.name);
        assert_eq!(
            run.executed + run.shed_admission + run.shed_deadline,
            run.arrivals,
            "{}: executed + sheds must equal arrivals",
            sc.name
        );
        assert_eq!(run.errors, 0, "{}: no Response::Error under any scenario", sc.name);
        if sc.name == "slow_reader" {
            assert!(
                run.shed_admission + run.shed_deadline > 0,
                "slow_reader is built to shed"
            );
        } else {
            assert_eq!(
                run.shed_admission + run.shed_deadline,
                0,
                "{}: sheds are intended only in slow_reader",
                sc.name
            );
        }
    }
}

#[test]
fn zipf_scenarios_concentrate_serves_in_top_decile_slots() {
    for (name, min_ratio) in [("zipf09", 1.3), ("zipf12", 1.5)] {
        let sc = Scenario::by_name(name).unwrap();
        let run = loadgen::run_scenario(&fleet(1, 48 * 1024), &sc, 7, 4).unwrap();
        let skew = run.doc.get("skew").unwrap();
        let ratio = skew.get("ratio").unwrap().as_f64().unwrap();
        let slots = skew.get("slots").unwrap().as_f64().unwrap();
        assert!(slots > 0.0, "{name}: expert census must be populated");
        assert!(
            ratio >= min_ratio,
            "{name}: top-decile slots should absorb a super-proportional \
             serve share (ratio {ratio:.2} < {min_ratio})"
        );
    }
}
