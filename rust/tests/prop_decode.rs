//! Relaxed-parity property harness for iteration-level decode batching.
//!
//! The bit-for-bit theorem of `prop_batching.rs` cannot survive decode
//! batching: step-major interleaving reorders the stateful cost model's
//! serve sequence, so a slot can be answered from a different arm (fused
//! vs dense) than the serial reference would pick. What CAN be pinned —
//! and is, here — is the relaxed contract:
//!
//! 1. **Greedy-sequence equality wherever decisions coincide.** Under a
//!    roomy budget every serve restores (bit-identical dense kernels on
//!    both sides), and under a zero budget every serve is fused
//!    (order-independent arithmetic), so in both regimes the batched
//!    Generate responses equal the sequential reference EXACTLY.
//! 2. **Conservation laws under every budget**, including the
//!    order-sensitive middle where outputs may legitimately differ:
//!    every admission is leased-or-refused (never dropped), every lease
//!    is returned, every produced sequence has the serial reference's
//!    length, and the cache answers every miss from exactly one arm.
//! 3. **Scheduler bookkeeping**: `DecodeScheduler` is a pure state
//!    machine, so its token-conservation identities are checked directly
//!    against seeded random admission/retirement traces.
//!
//! The quantitative side of the contract — per-token logit relative
//! error across arm flips stays within the float-summation-order bound —
//! lives in the seeded simulation `scripts/sim_decode.py`, where logits
//! are observable; `scripts/check_decode.py` gates its report.

use resmoe::compress::{compress_model, CompressedModel, ResMoE};
use resmoe::coordinator::{DecodePolicy, DecodeScheduler, Engine, Request, Response};
use resmoe::moe::{Model, ModelConfig};
use resmoe::store::pack_compressed_model;
use resmoe::util::prop::{check, PropConfig};
use resmoe::util::Rng;
use std::path::PathBuf;

/// 4 layers → MoE blocks 1 and 3, the geometry the batching harness uses.
fn base_model(seed: u64) -> Model {
    let mut cfg = ModelConfig::switch_mini(4);
    cfg.d_model = 16;
    cfg.d_inner = 32;
    cfg.n_layers = 4;
    cfg.n_heads = 2;
    cfg.vocab_size = 32;
    cfg.max_seq = 32;
    let mut rng = Rng::new(seed);
    Model::random(&cfg, &mut rng)
}

fn one_expert_bytes() -> usize {
    (32 * (2 * 16 + 1) + 16) * 4
}

struct Combo {
    name: String,
    model: Model,
    cm: CompressedModel,
    artifact: PathBuf,
}

fn combos() -> Vec<Combo> {
    let dir = std::env::temp_dir().join("resmoe-prop-decode");
    std::fs::create_dir_all(&dir).unwrap();
    let model = base_model(2000);
    let mut out = Vec::new();
    for (mname, method, rate) in [
        ("up", ResMoE::up(), 0.25f64),
        ("svd", ResMoE::svd(), 0.25),
        ("up", ResMoE::up(), 1.0),
    ] {
        let mut rng = Rng::new(11 + (rate * 8.0) as u64);
        let cm = compress_model(&model, &method, rate, 2, None, &mut rng);
        let artifact = dir.join(format!("{mname}-{rate}.rmes"));
        pack_compressed_model(&model, &cm.layers, rate, &artifact).unwrap();
        out.push(Combo { name: format!("{mname}@{rate}"), model: model.clone(), cm, artifact });
    }
    out
}

#[derive(Debug)]
struct Case {
    combo: usize,
    budget: usize,
    packed: bool,
    decode_max: usize,
    reqs: Vec<Request>,
}

/// 2–8 valid Generate requests (short prompts, 0–4 new tokens): a pure
/// decode run, so `handle_batch` routes the whole window through the
/// decode lane.
fn gen_generates(rng: &mut Rng) -> Vec<Request> {
    let n = 2 + rng.below(7);
    (0..n)
        .map(|_| Request::Generate {
            prompt: (0..1 + rng.below(4)).map(|_| rng.below(32) as u32).collect(),
            max_new: rng.below(5),
        })
        .collect()
}

fn engines_for(case: &Case, combos: &[Combo]) -> (Engine, Engine) {
    let c = &combos[case.combo];
    let (mut serial, mut batched) = if case.packed {
        let mut serial = Engine::from_store(&c.artifact, case.budget).unwrap();
        serial.disable_prefetch();
        let mut batched = Engine::from_store(&c.artifact, case.budget).unwrap();
        batched.disable_prefetch();
        (serial, batched)
    } else {
        (
            Engine::compressed(c.model.clone(), c.cm.layers.clone(), case.budget),
            Engine::compressed(c.model.clone(), c.cm.layers.clone(), case.budget),
        )
    };
    serial.set_decode_batch(1); // the sequential reference
    batched.set_decode_batch(case.decode_max);
    (serial, batched)
}

/// Conservation laws that hold under EVERY budget, checked after a
/// batched window: admission accounting, lease churn, and the cache's
/// one-arm-per-miss identity.
fn check_conservation(engine: &Engine, n_reqs: u64) -> Result<(), String> {
    let dm = engine.decode_metrics();
    if dm.seqs + dm.solo_fallbacks != n_reqs {
        return Err(format!("admissions not conserved over {n_reqs} reqs: {dm:?}"));
    }
    if dm.kv_leases != dm.seqs || dm.kv_refusals != dm.solo_fallbacks {
        return Err(format!("one lease per batched sequence violated: {dm:?}"));
    }
    let bm = engine.batch_metrics();
    if bm.batched_requests != dm.seqs || bm.solo_requests != dm.solo_fallbacks {
        return Err(format!("batch counters disagree with decode counters: {bm:?} {dm:?}"));
    }
    let pool = engine.kv_pool();
    if pool.used_bytes() != 0 {
        return Err(format!("{} KV bytes leaked past retirement", pool.used_bytes()));
    }
    if pool.leases_granted() != pool.leases_released() || pool.leases_granted() != dm.kv_leases
    {
        return Err(format!(
            "lease churn not conserved: granted {} released {} counted {}",
            pool.leases_granted(),
            pool.leases_released(),
            dm.kv_leases
        ));
    }
    if pool.refusals() != dm.kv_refusals {
        return Err("pool refusals disagree with decode counters".into());
    }
    if dm.steps > 0 {
        let mean = dm.mean_step_batch();
        if !(1.0..=8.0).contains(&mean) {
            return Err(format!("mean step batch {mean} outside [1, max_batch]"));
        }
    }
    if let Some(cm) = engine.cache_metrics() {
        if cm.misses != cm.restore_serves + cm.fused_serves + cm.degraded_serves {
            return Err(format!("miss not answered by exactly one arm: {cm:?}"));
        }
    }
    Ok(())
}

#[test]
fn prop_batched_decode_matches_serial_where_decisions_coincide() {
    // Regime 1 of the relaxed contract: roomy (all-restore) and zero
    // (all-fused) budgets make the cost model order-independent, so the
    // batched decode lane must reproduce the sequential reference
    // bitwise — including its greedy token sequences.
    let combos = combos();
    let n_combos = combos.len();
    check(
        PropConfig { cases: 18, seed: 0xDEC0D1 },
        |rng| Case {
            combo: rng.below(n_combos),
            budget: [usize::MAX, 0][rng.below(2)],
            packed: rng.below(2) == 1,
            decode_max: [2, 3, 8][rng.below(3)],
            reqs: gen_generates(rng),
        },
        |case| {
            let (serial, batched) = engines_for(case, &combos);
            let want: Vec<Response> = case.reqs.iter().map(|r| serial.handle(r)).collect();
            let got = batched.handle_batch(&case.reqs);
            if got != want {
                return Err(format!(
                    "{} budget {} decode_max {}: batched decode != serial\n got {got:?}\nwant {want:?}",
                    combos[case.combo].name, case.budget, case.decode_max
                ));
            }
            check_conservation(&batched, case.reqs.len() as u64)
        },
    );
}

#[test]
fn prop_decode_conserves_under_order_sensitive_budgets() {
    // Regime 2: tight budgets where the interleaved serve order may
    // legitimately flip fused-vs-dense arms. Token sequences are not
    // compared — instead every structural law must hold, and every
    // response must still be a well-formed Generate of the serial
    // reference's LENGTH (the scheduler's produce condition is
    // budget-independent).
    let combos = combos();
    let n_combos = combos.len();
    let e = one_expert_bytes();
    check(
        PropConfig { cases: 18, seed: 0xDEC0D2 },
        |rng| Case {
            combo: rng.below(n_combos),
            budget: [2 * e, 3 * e, 4 * e][rng.below(3)],
            packed: rng.below(2) == 1,
            decode_max: [2, 3, 8][rng.below(3)],
            reqs: gen_generates(rng),
        },
        |case| {
            let (_, batched) = engines_for(case, &combos);
            let got = batched.handle_batch(&case.reqs);
            for (resp, req) in got.iter().zip(&case.reqs) {
                let Request::Generate { prompt, max_new } = req else { unreachable!() };
                let want_len = (*max_new).min(32 - prompt.len());
                let toks = match resp {
                    Response::Generate(t) => t,
                    Response::Degraded(inner) => match inner.as_ref() {
                        Response::Generate(t) => t,
                        other => return Err(format!("degraded non-generate: {other:?}")),
                    },
                    other => return Err(format!("unexpected response: {other:?}")),
                };
                if toks.len() != want_len {
                    return Err(format!(
                        "produced {} tokens, serial reference produces {want_len}",
                        toks.len()
                    ));
                }
                if toks.iter().any(|&t| t >= 32) {
                    return Err("token outside vocabulary".into());
                }
            }
            check_conservation(&batched, case.reqs.len() as u64)
        },
    );
}

#[test]
fn prop_scheduler_token_bookkeeping_is_conserved() {
    // The scheduler alone, against seeded random admission traces with
    // synthetic logits: `admitted == finished + active` after every
    // step, plans iterate in admission order, and every retired
    // sequence satisfies the `fed` identity.
    #[derive(Debug)]
    struct Trace {
        max_batch: usize,
        max_seq: usize,
        seqs: Vec<(usize, usize)>, // (prompt_len, max_new)
        seed: u64,
    }
    check(
        PropConfig { cases: 60, seed: 0xDEC0D3 },
        |rng| Trace {
            max_batch: 1 + rng.below(4),
            max_seq: 6 + rng.below(6),
            seqs: (0..1 + rng.below(10))
                .map(|_| (1 + rng.below(5), rng.below(6)))
                .collect(),
            seed: rng.below(1 << 30) as u64,
        },
        |t| {
            let mut sched = DecodeScheduler::new(DecodePolicy { max_batch: t.max_batch });
            let mut lrng = Rng::new(t.seed);
            let mut pending: Vec<(usize, usize)> = t
                .seqs
                .iter()
                .map(|&(p, n)| (p.min(t.max_seq - 1).max(1), n))
                .collect();
            let mut expected = std::collections::HashMap::new();
            let mut fed_total = 0u64;
            let mut retired = 0usize;
            while retired < t.seqs.len() {
                // Admit a random number of pending sequences into free
                // slots (always at least one when the scheduler is idle,
                // so the trace cannot stall).
                while sched.has_room()
                    && !pending.is_empty()
                    && (sched.is_idle() || lrng.below(3) > 0)
                {
                    let (p, n) = pending.pop().unwrap();
                    let prompt: Vec<u32> = (0..p).map(|_| lrng.below(16) as u32).collect();
                    let ticket = sched.admit(prompt, n, t.max_seq);
                    expected.insert(ticket, (p, n.min(t.max_seq - p)));
                }
                let plan = sched.plan();
                if plan.is_empty() {
                    if pending.is_empty() {
                        return Err("scheduler idle with sequences unretired".into());
                    }
                    continue;
                }
                if plan.len() != sched.active() {
                    return Err("plan must cover every active sequence".into());
                }
                if plan.windows(2).any(|w| w[0].0 >= w[1].0) {
                    return Err("plan not in admission (ticket) order".into());
                }
                let logits: Vec<Vec<f32>> = plan
                    .iter()
                    .map(|_| (0..16).map(|_| lrng.below(1 << 16) as f32 * 1e-3).collect())
                    .collect();
                fed_total += logits.len() as u64;
                for fin in sched.record(&logits) {
                    retired += 1;
                    let (p, want_new) = expected.remove(&fin.ticket).expect("known ticket");
                    if fin.prompt_len != p || fin.produced.len() != want_new {
                        return Err(format!(
                            "ticket {}: produced {} of {want_new} expected tokens",
                            fin.ticket,
                            fin.produced.len()
                        ));
                    }
                    if fin.fed != p + fin.produced.len().max(1) - 1 {
                        return Err(format!("fed identity violated: {fin:?}"));
                    }
                }
                if sched.admitted() != sched.finished() + sched.active() as u64 {
                    return Err("admitted != finished + active".into());
                }
            }
            if !sched.is_idle() || !expected.is_empty() {
                return Err("sequences left behind after drain".into());
            }
            if sched.tokens_fed() != fed_total {
                return Err(format!(
                    "tokens_fed {} != rows recorded {fed_total}",
                    sched.tokens_fed()
                ));
            }
            Ok(())
        },
    );
}
