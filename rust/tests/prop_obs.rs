//! Observability properties: trace completeness (every request produces
//! exactly one JSONL line whose spans nest and sum within the measured
//! wall time), bit-for-bit parity of the serving path with tracing on vs
//! off, and silence when tracing is disabled.
//!
//! All tests serialize on `trace::test_serial()` — the trace switch and the
//! in-memory sink are process globals, the test runner is not.

use resmoe::compress::{compress_model, ResMoE};
use resmoe::coordinator::{Engine, Request, Response, Server, ServerConfig};
use resmoe::moe::{Model, ModelConfig};
use resmoe::obs::trace;
use resmoe::util::json::Json;
use resmoe::Rng;
use std::collections::HashSet;

fn model(seed: u64) -> Model {
    let mut cfg = ModelConfig::switch_mini(4);
    cfg.d_model = 16;
    cfg.d_inner = 32;
    cfg.n_layers = 4;
    cfg.n_heads = 2;
    cfg.vocab_size = 32;
    cfg.max_seq = 40;
    let mut rng = Rng::new(seed);
    Model::random(&cfg, &mut rng)
}

fn compressed_engine(m: &Model, budget: usize, seed: u64) -> Engine {
    let mut rng = Rng::new(seed);
    let cm = compress_model(m, &ResMoE::up(), 0.25, 2, None, &mut rng);
    Engine::compressed(m.clone(), cm.layers, budget)
}

fn mixed_requests(n: usize) -> Vec<Request> {
    (0..n)
        .map(|i| {
            if i % 3 == 1 {
                Request::Generate { prompt: vec![1, 2, 3], max_new: 4 }
            } else {
                Request::Score {
                    tokens: (0..10).map(|t| ((t * (i + 2)) % 32) as u32).collect(),
                }
            }
        })
        .collect()
}

/// Validate one JSONL trace line: parses, spans stay within `wall_ns`,
/// every depth-d span (d > 0) is enclosed by a depth-(d-1) span, and
/// depth-0 spans sum to at most the wall. Returns (attributed fraction,
/// stage names seen).
fn check_line(line: &str) -> (f64, HashSet<String>) {
    let j = Json::parse(line).unwrap_or_else(|e| panic!("unparseable trace line {e:?}: {line}"));
    let wall = j.get("wall_ns").and_then(|v| v.as_f64()).expect("wall_ns");
    let queue = j.get("queue_ns").and_then(|v| v.as_f64()).expect("queue_ns");
    assert!(wall > 0.0, "zero wall: {line}");
    assert!(queue <= wall, "queue {queue} beyond wall {wall}: {line}");
    let spans = j.get("spans").and_then(|v| v.as_arr()).expect("spans");
    assert!(!spans.is_empty(), "traced request with no spans: {line}");
    let parsed: Vec<(f64, f64, f64, String)> = spans
        .iter()
        .map(|s| {
            (
                s.get("t0").and_then(|v| v.as_f64()).expect("t0"),
                s.get("dur").and_then(|v| v.as_f64()).expect("dur"),
                s.get("depth").and_then(|v| v.as_f64()).expect("depth"),
                s.get("stage").and_then(|v| v.as_str()).expect("stage").to_string(),
            )
        })
        .collect();
    let mut covered = 0.0;
    for (t0, dur, depth, stage) in &parsed {
        assert!(
            t0 + dur <= wall + 0.5,
            "span {stage} [{t0}, {t0}+{dur}] beyond wall {wall}: {line}"
        );
        if *depth > 0.0 {
            let enclosed = parsed.iter().any(|(pt0, pdur, pdepth, _)| {
                *pdepth == depth - 1.0 && *pt0 <= *t0 && pt0 + pdur >= t0 + dur
            });
            assert!(enclosed, "depth-{depth} span {stage} has no enclosing parent: {line}");
        }
        if *depth == 0.0 {
            covered += dur;
        }
    }
    // Depth-0 spans are sequential stages of one request — their sum can
    // never exceed the measured wall.
    assert!(covered <= wall + 0.5, "depth-0 spans exceed wall ({covered} > {wall}): {line}");
    (covered / wall, parsed.into_iter().map(|(_, _, _, s)| s).collect())
}

#[test]
fn every_serial_request_emits_exactly_one_well_formed_line() {
    let _g = trace::test_serial();
    trace::force_for_tests(Some(true));
    trace::drain_test_lines();
    let m = model(40);
    let engine = compressed_engine(&m, usize::MAX, 41);
    let reqs = mixed_requests(12);
    for r in &reqs {
        engine.handle(r);
    }
    let lines = trace::drain_test_lines();
    trace::force_for_tests(None);
    assert_eq!(lines.len(), reqs.len(), "one trace line per request");
    let mut req_ids = HashSet::new();
    for line in &lines {
        let (coverage, stages) = check_line(line);
        assert!(
            coverage >= 0.85,
            "named stages attribute only {:.0} % of wall: {line}",
            coverage * 100.0
        );
        assert!(
            stages.contains("forward") || stages.contains("decode"),
            "no top-level execution stage: {line}"
        );
        let id = Json::parse(line).unwrap().get("req").unwrap().as_f64().unwrap() as u64;
        assert!(req_ids.insert(id), "duplicate request id {id}");
    }
    let generates = lines
        .iter()
        .filter(|l| {
            Json::parse(l).unwrap().get("kind").and_then(|v| v.as_str().map(String::from))
                == Some("generate".into())
        })
        .count();
    assert_eq!(generates, reqs.len() / 3, "request kinds round-trip into trace lines");
}

#[test]
fn batched_windows_emit_one_line_per_member_request() {
    let _g = trace::test_serial();
    trace::force_for_tests(Some(true));
    trace::drain_test_lines();
    let m = model(42);
    let engine = compressed_engine(&m, usize::MAX, 43);
    let server = Server::start(
        engine,
        ServerConfig { batch_max: 4, batch_wait_us: 200, workers: 2, ..Default::default() },
    );
    let n = 16usize;
    let replies: Vec<_> = (0..n)
        .map(|i| {
            server.submit(Request::Score {
                tokens: (0..8).map(|t| ((t + i) % 32) as u32).collect(),
            })
        })
        .collect();
    for r in replies {
        r.recv().unwrap();
    }
    server.shutdown();
    let lines = trace::drain_test_lines();
    trace::force_for_tests(None);
    assert_eq!(lines.len(), n, "batched window must fan out one line per member");
    let mut queue_waits = 0usize;
    for line in &lines {
        let (coverage, stages) = check_line(line);
        assert!(
            coverage >= 0.75,
            "window stages attribute only {:.0} % of wall: {line}",
            coverage * 100.0
        );
        if stages.contains("queue.wait") {
            queue_waits += 1;
        }
    }
    assert!(
        queue_waits > 0,
        "admission-window serving must record queue.wait on at least one request"
    );
}

#[test]
fn tracing_toggle_leaves_responses_and_counters_bit_identical() {
    let _g = trace::test_serial();
    trace::drain_test_lines();
    let m = model(44);
    let reqs = mixed_requests(18);
    // A budget of ~4 experts across the compressed layers forces misses,
    // restores, and evictions — the counter-heavy paths where an
    // observation-feeds-back bug would show up.
    let run = |traced: bool| {
        trace::force_for_tests(Some(traced));
        let engine = compressed_engine(&m, 1 << 14, 45);
        let out: Vec<Response> = reqs.iter().map(|r| engine.handle(r)).collect();
        let counters = format!("{:?}", engine.cache_metrics().unwrap());
        (out, counters)
    };
    let (off, counters_off) = run(false);
    let (on, counters_on) = run(true);
    trace::drain_test_lines();
    trace::force_for_tests(None);
    for (a, b) in off.iter().zip(&on) {
        match (a, b) {
            (Response::Score(x), Response::Score(y)) => {
                assert_eq!(x.to_bits(), y.to_bits(), "score diverged under tracing")
            }
            (Response::Generate(x), Response::Generate(y)) => {
                assert_eq!(x, y, "generation diverged under tracing")
            }
            other => panic!("response kind diverged: {other:?}"),
        }
    }
    assert_eq!(counters_off, counters_on, "cache counter sequence diverged under tracing");
}

#[test]
fn disabled_tracing_emits_no_lines_from_the_full_stack() {
    let _g = trace::test_serial();
    trace::force_for_tests(Some(false));
    trace::drain_test_lines();
    let m = model(46);
    let engine = compressed_engine(&m, usize::MAX, 47);
    for r in &mixed_requests(6) {
        engine.handle(r);
    }
    let server = Server::start(engine, ServerConfig::default());
    let r = server.submit(Request::Score { tokens: vec![1, 2, 3] });
    r.recv().unwrap();
    server.shutdown();
    let leaked = trace::drain_test_lines();
    trace::force_for_tests(None);
    assert!(leaked.is_empty(), "disabled tracing leaked {} lines", leaked.len());
}

#[test]
fn packed_store_traces_name_the_store_stages() {
    let _g = trace::test_serial();
    trace::force_for_tests(Some(true));
    trace::drain_test_lines();
    use resmoe::store::pack_compressed_model;
    let m = model(48);
    let mut rng = Rng::new(49);
    let cm = compress_model(&m, &ResMoE::up(), 0.25, 2, None, &mut rng);
    let dir = std::env::temp_dir().join("resmoe-prop-obs-store");
    std::fs::create_dir_all(&dir).unwrap();
    let artifact = dir.join("trace.rmes");
    pack_compressed_model(&m, &cm.layers, 0.25, &artifact).unwrap();
    let engine = Engine::from_store(&artifact, usize::MAX).unwrap();
    let reqs = mixed_requests(6);
    for r in &reqs {
        engine.handle(r);
    }
    engine.quiesce_prefetch();
    let lines = trace::drain_test_lines();
    trace::force_for_tests(None);
    assert_eq!(lines.len(), reqs.len());
    let mut stages = HashSet::new();
    for line in &lines {
        stages.extend(check_line(line).1);
    }
    // Demand paging ran on the traced serving thread, so the store stages
    // must show up under the MoE serving spans.
    for want in ["moe.block", "moe.serve", "cache.shard_fetch", "store.read", "store.crc", "store.decode"]
    {
        assert!(stages.contains(want), "stage {want} missing from packed-store traces: {stages:?}");
    }
}
