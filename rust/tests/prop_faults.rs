//! Chaos suite for the fault-tolerant serving path (`util::fault` +
//! `coordinator::cache` retry/quarantine/degrade + `coordinator::server`
//! admission control), driven by seeded deterministic fault plans.
//!
//! The invariants, in the order the stack establishes them:
//!
//! 1. **Parity pin** — with faults disabled the whole stack is bit-for-bit
//!    the fault-free server: identical responses, identical cache
//!    decisions, fault counters pinned at zero.
//! 2. **Convergence** — a transient-only storm that exhausts before the
//!    retry budget produces responses *bitwise equal* to the fault-free
//!    run, because retries live entirely inside the singleflight
//!    materialize and never change a cache decision.
//! 3. **Degradation** — a permanently corrupt residual shard is answered
//!    by the resident barycenter center ([`Serve::Degraded`], the paper's
//!    rate→0 limit), quarantined after repeated failures, and surfaced to
//!    clients as [`Response::Degraded`] — never a panic, never silence.
//! 4. **Attribution** — when no center exists to degrade onto, errors pin
//!    to exactly the requests whose experts failed, identically in the
//!    serial and batched window paths.
//! 5. **Liveness** — probabilistic storms under concurrency answer every
//!    request and leak no singleflight flight.
//!
//! Every test that flips the global fault override holds
//! [`fault::test_serial`] so the in-process suite serializes; tests that
//! never touch the store (admission control) run in parallel as usual.

use resmoe::compress::{compress_model, ResMoE};
use resmoe::coordinator::{
    CacheMetrics, Engine, ExpertCache, Request, Response, Serve, Server, ServerConfig,
};
use resmoe::moe::{Model, ModelConfig};
use resmoe::store::{pack_compressed_model, ExpertStore, Prefetcher};
use resmoe::util::fault::{self, FaultPlan};
use resmoe::{Matrix, Rng};
use std::path::PathBuf;
use std::sync::Arc;

// ------------------------------------------------------------- fixtures

fn tiny_model(seed: u64) -> Model {
    let mut cfg = ModelConfig::switch_mini(4);
    cfg.d_model = 16;
    cfg.d_inner = 32;
    cfg.n_layers = 2;
    cfg.n_heads = 2;
    cfg.vocab_size = 32;
    cfg.max_seq = 32;
    let mut rng = Rng::new(seed);
    Model::random(&cfg, &mut rng)
}

/// Bytes of one restored dense expert of the tiny model (w1 + w2 + biases).
const ONE_EXPERT: usize = 32 * (2 * 16 + 1) * 4 + 16 * 4;

/// Compress the tiny model with ResMoE and pack it to a store artifact.
/// `strip_centers` removes the shared barycenter from every layer before
/// packing — the configuration where degraded serving is impossible and
/// store faults must surface as per-request errors.
fn pack_artifact(seed: u64, name: &str, strip_centers: bool) -> PathBuf {
    let m = tiny_model(seed);
    let mut rng = Rng::new(seed ^ 0x00C0_FFEE);
    let mut cm = compress_model(&m, &ResMoE::up(), 0.25, 2, None, &mut rng);
    if strip_centers {
        for (_, cl) in &mut cm.layers {
            cl.base = None;
        }
    }
    let dir = std::env::temp_dir().join("resmoe-prop-faults");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("{name}-{seed}.rmes"));
    pack_compressed_model(&m, &cm.layers, 0.25, &path).unwrap();
    path
}

fn score_requests(n: usize, len: usize) -> Vec<Request> {
    (0..n)
        .map(|i| Request::Score {
            tokens: (0..len).map(|t| ((t * 7 + i * 13 + 1) % 32) as u32).collect(),
        })
        .collect()
}

/// Exact structural equality — scores compare by f64 *bit pattern*.
fn resp_eq(a: &Response, b: &Response) -> bool {
    match (a, b) {
        (Response::Score(x), Response::Score(y)) => x.to_bits() == y.to_bits(),
        (Response::Generate(x), Response::Generate(y)) => x == y,
        (Response::Classify(x), Response::Classify(y)) => x == y,
        (Response::Error(x), Response::Error(y)) => x == y,
        (Response::Overloaded(x), Response::Overloaded(y)) => x == y,
        (Response::Degraded(x), Response::Degraded(y)) => resp_eq(x, y),
        (Response::Metrics(_), Response::Metrics(_)) => true,
        _ => false,
    }
}

fn serve_kind(s: &Serve) -> &'static str {
    match s {
        Serve::Dense(_) => "dense",
        Serve::Fused(_) => "fused",
        Serve::Paged { .. } => "paged",
        Serve::Degraded(_) => "degraded",
    }
}

/// Every counter that reflects a cache *decision* (as opposed to wall-time
/// or fault bookkeeping) must be unperturbed by retried transients.
fn assert_decisions_eq(clean: &CacheMetrics, faulted: &CacheMetrics) {
    assert_eq!(clean.hits, faulted.hits, "hits diverged");
    assert_eq!(clean.misses, faulted.misses, "misses diverged");
    assert_eq!(clean.restore_serves, faulted.restore_serves, "restore decisions diverged");
    assert_eq!(clean.fused_serves, faulted.fused_serves, "fused decisions diverged");
    assert_eq!(clean.restores_executed, faulted.restores_executed, "restores diverged");
    assert_eq!(clean.shard_fetches, faulted.shard_fetches, "shard fetches diverged");
    assert_eq!(clean.shard_bytes, faulted.shard_bytes, "shard bytes diverged");
    assert_eq!(clean.evictions, faulted.evictions, "evictions diverged");
    assert_eq!(clean.shard_evictions, faulted.shard_evictions, "shard evictions diverged");
    assert_eq!(clean.quant_serves, faulted.quant_serves, "quant serves diverged");
    assert_eq!(clean.batch_windows, faulted.batch_windows, "batch windows diverged");
    assert_eq!(clean.prefetch_hits, faulted.prefetch_hits, "prefetch hits diverged");
    assert_eq!(clean.prefetch_misses, faulted.prefetch_misses, "prefetch misses diverged");
}

fn fault_counter_sum(m: &CacheMetrics) -> u64 {
    m.transient_errors + m.fetch_retries + m.quarantined_shards + m.degraded_serves
        + m.prefetch_errors
}

// ----------------------------------------------------------- invariants

/// With no plan installed, the forced-off override and the env-following
/// path answer identically and never touch a fault counter — the pin that
/// keeps every pre-existing bit-parity suite meaningful.
#[test]
fn fault_disabled_parity_pin() {
    let _guard = fault::test_serial();
    if std::env::var("RESMOE_FAULTS").is_ok() {
        return; // the pin is only meaningful in a fault-free environment
    }
    let art = pack_artifact(11, "parity", false);
    let reqs = score_requests(10, 8);

    fault::force_disabled_for_tests();
    let mut off = Engine::from_store(&art, usize::MAX).unwrap();
    off.disable_prefetch();
    let r_off: Vec<Response> = reqs.iter().map(|r| off.handle(r)).collect();
    let m_off = off.cache_metrics().unwrap();

    fault::force_for_tests(None); // follow the (unset) environment
    let mut env = Engine::from_store(&art, usize::MAX).unwrap();
    env.disable_prefetch();
    let r_env: Vec<Response> = reqs.iter().map(|r| env.handle(r)).collect();
    let m_env = env.cache_metrics().unwrap();

    for (a, b) in r_off.iter().zip(&r_env) {
        assert!(resp_eq(a, b), "disabled vs env-follow diverged: {a:?} vs {b:?}");
        assert!(matches!(a, Response::Score(_)), "healthy run must not degrade: {a:?}");
    }
    assert_eq!(fault_counter_sum(&m_off), 0, "fault counters must stay zero: {m_off:?}");
    assert_eq!(fault_counter_sum(&m_env), 0, "fault counters must stay zero: {m_env:?}");
    assert_decisions_eq(&m_off, &m_env);
}

/// A transient storm that exhausts before the retry budget (`*2` faults vs
/// a 3-retry budget) converges **bitwise** to the fault-free run — under a
/// roomy budget and under an eviction-heavy one — because every fetch
/// still succeeds inside its own singleflight materialize.
#[test]
fn transient_storm_converges_bitwise_to_fault_free() {
    let _guard = fault::test_serial();
    let art = pack_artifact(21, "storm", false);
    let mut reqs = score_requests(12, 8);
    reqs.extend(score_requests(12, 8)); // second pass: exercise hits too

    for budget in [usize::MAX, 2 * ONE_EXPERT] {
        fault::force_disabled_for_tests();
        let mut clean = Engine::from_store(&art, budget).unwrap();
        clean.disable_prefetch();
        let want: Vec<Response> = reqs.iter().map(|r| clean.handle(r)).collect();
        let m_clean = clean.cache_metrics().unwrap();

        let plan = FaultPlan::parse("seed:7,spec:transient@store.read*2").unwrap();
        fault::force_for_tests(Some(plan));
        let mut faulted = Engine::from_store(&art, budget).unwrap();
        faulted.disable_prefetch();
        let got: Vec<Response> = reqs.iter().map(|r| faulted.handle(r)).collect();
        let m_faulted = faulted.cache_metrics().unwrap();
        fault::force_for_tests(None);

        for (w, g) in want.iter().zip(&got) {
            assert!(resp_eq(w, g), "budget {budget}: {w:?} vs {g:?}");
            assert!(matches!(g, Response::Score(_)), "converged storm must not degrade: {g:?}");
        }
        assert!(m_faulted.transient_errors > 0, "the storm must actually fire");
        assert_eq!(
            m_faulted.transient_errors, m_faulted.fetch_retries,
            "every injected transient (2 < budget 3) is followed by one retry"
        );
        assert_eq!(m_faulted.quarantined_shards, 0, "converging storm never quarantines");
        assert_eq!(m_faulted.degraded_serves, 0, "converging storm never degrades");
        assert_decisions_eq(&m_clean, &m_faulted);
    }
}

/// Permanently corrupt residual shards (CRC trips on every read): the slot
/// is served by the barycenter center alone — bitwise equal to the
/// center's own forward — the shard quarantines after the failure
/// threshold, and *other* blocks keep serving exactly.
#[test]
fn corrupt_shards_degrade_to_barycenter_and_quarantine() {
    let _guard = fault::test_serial();
    let art = pack_artifact(31, "degrade", false);
    let store = Arc::new(ExpertStore::open(&art).unwrap());
    let blocks = store.blocks();
    let bad = blocks[0];
    let x = Matrix::from_fn(2, 16, |r, c| ((r * 16 + c) as f32 * 0.03).sin());

    // Clean reference: the block's densified center (batch-1 store serves
    // page restore-free, so the center rides along in `Serve::Paged`).
    fault::force_disabled_for_tests();
    let clean = ExpertCache::from_store(store.clone(), usize::MAX).unwrap();
    let center = match clean.try_serve(bad, 0, 1).unwrap() {
        Serve::Paged { center, .. } => center,
        other => panic!("store-mode batch-1 serve should page, got {}", serve_kind(&other)),
    };
    let center_out = center.forward(&x);

    let plan =
        FaultPlan::parse(&format!("seed:1,spec:corrupt@store.read/b{bad}")).unwrap();
    fault::force_for_tests(Some(plan));
    let cache = ExpertCache::from_store(store.clone(), usize::MAX).unwrap();
    for round in 0..4 {
        for slot in 0..4 {
            match cache.try_serve(bad, slot, x.rows).unwrap() {
                Serve::Degraded(c) => assert_eq!(
                    c.forward(&x),
                    center_out,
                    "degraded answer must be the barycenter-only forward"
                ),
                other => panic!(
                    "round {round} slot {slot}: want degraded, got {}",
                    serve_kind(&other)
                ),
            }
        }
    }
    let m = cache.metrics();
    assert!(m.degraded_serves >= 16, "every serve of the bad block degrades: {m:?}");
    assert!(m.quarantined_shards >= 1, "3+ consecutive failures must quarantine: {m:?}");
    assert_eq!(m.transient_errors, 0, "integrity failures are never retried");
    assert_eq!(m.fetch_retries, 0, "integrity failures are never retried");

    // Blocks outside the blast radius restore bit-identically.
    if let Some(&ok) = blocks.iter().find(|&&b| b != bad) {
        let w_clean = clean.try_get(ok, 1).unwrap();
        let w_fault = cache.try_get(ok, 1).unwrap();
        assert_eq!(w_clean.forward(&x), w_fault.forward(&x), "healthy block perturbed");
    }
    fault::force_for_tests(None);
}

/// End-to-end degraded marking: with every residual unreadable, serial
/// handling, batched windows, and the concurrent server all answer
/// `Response::Degraded(Score)` — bitwise identical across the three paths.
#[test]
fn server_marks_degraded_answers_identically_across_paths() {
    let _guard = fault::test_serial();
    let art = pack_artifact(41, "server-degrade", false);
    let reqs = score_requests(8, 6);
    let plan = FaultPlan::parse("seed:2,spec:corrupt@store.read").unwrap();
    fault::force_for_tests(Some(plan));

    let mut serial = Engine::from_store(&art, usize::MAX).unwrap();
    serial.disable_prefetch();
    let want: Vec<Response> = reqs.iter().map(|r| serial.handle(r)).collect();
    for w in &want {
        match w {
            Response::Degraded(inner) => {
                assert!(matches!(**inner, Response::Score(_)), "inner must be the answer")
            }
            other => panic!("all-corrupt store must mark every answer degraded: {other:?}"),
        }
    }
    assert!(serial.cache_metrics().unwrap().degraded_serves > 0);

    // Batched windows pin the same marker per request.
    let mut batch_engine = Engine::from_store(&art, usize::MAX).unwrap();
    batch_engine.disable_prefetch();
    let batched = batch_engine.handle_batch(&reqs);
    for (i, (w, g)) in want.iter().zip(&batched).enumerate() {
        assert!(resp_eq(w, g), "request {i}: serial {w:?} vs batched {g:?}");
    }

    // The concurrent server round-trips the marker untouched.
    let mut server_engine = Engine::from_store(&art, usize::MAX).unwrap();
    server_engine.disable_prefetch();
    let server = Server::start(
        server_engine,
        ServerConfig { batch_max: 4, batch_wait_us: 100, workers: 2, ..Default::default() },
    );
    let rxs: Vec<_> = reqs.iter().map(|r| server.submit(r.clone())).collect();
    for (rx, w) in rxs.into_iter().zip(&want) {
        let (got, _) = rx.recv().unwrap();
        assert!(resp_eq(&got, w), "server: {got:?} vs {w:?}");
    }
    server.shutdown();
    fault::force_for_tests(None);

    // into_inner unwraps the marker for clients that prefer the value.
    match Response::Degraded(Box::new(Response::Score(0.5))).into_inner() {
        Response::Score(s) => assert_eq!(s, 0.5),
        other => panic!("into_inner must unwrap: {other:?}"),
    }
}

/// No center to degrade onto (stripped at pack time): store failures
/// surface as `Response::Error` pinned to exactly the requests whose
/// routed experts failed — and the batched window path reproduces the
/// serial attribution (same requests, same messages) even across the
/// quarantine threshold, because per-want cold replays fail in the same
/// per-target order serial serving does.
#[test]
fn center_less_store_pins_errors_per_request() {
    let _guard = fault::test_serial();
    let art = pack_artifact(51, "no-center", true);
    let bad = {
        let store = ExpertStore::open(&art).unwrap();
        store.blocks()[0]
    };
    let plan =
        FaultPlan::parse(&format!("seed:3,spec:corrupt@store.read/b{bad}e0")).unwrap();
    fault::force_for_tests(Some(plan));
    let reqs = score_requests(8, 6);

    let mut serial = Engine::from_store(&art, usize::MAX).unwrap();
    serial.disable_prefetch();
    let want: Vec<Response> = reqs.iter().map(|r| serial.handle(r)).collect();
    let errors = want.iter().filter(|r| matches!(r, Response::Error(_))).count();
    assert!(errors > 0, "a corrupt shard with no center must surface Response::Error");
    for w in &want {
        match w {
            Response::Error(msg) => assert!(
                msg.contains(&format!("expert serve failed for block {bad}")),
                "error must name the failing block: {msg}"
            ),
            Response::Score(_) => {}
            other => panic!("center-less store can error or answer, never degrade: {other:?}"),
        }
    }

    let mut batch_engine = Engine::from_store(&art, usize::MAX).unwrap();
    batch_engine.disable_prefetch();
    let batched = batch_engine.handle_batch(&reqs);
    for (i, (w, g)) in want.iter().zip(&batched).enumerate() {
        assert!(resp_eq(w, g), "request {i}: serial {w:?} vs batched {g:?}");
    }
    fault::force_for_tests(None);
}

/// Probabilistic transient storm under concurrency and an eviction-heavy
/// budget: every serve answers `Ok` (the center absorbs permanent
/// failures), no singleflight flight leaks, and the storm demonstrably
/// fired.
#[test]
fn concurrent_storm_liveness_and_no_leaked_flights() {
    let _guard = fault::test_serial();
    let art = pack_artifact(61, "concurrent", false);
    let store = Arc::new(ExpertStore::open(&art).unwrap());
    let blocks = store.blocks();

    for clients in [1usize, 2, 8] {
        let plan = FaultPlan::parse("seed:11,spec:transient@store.read~0.6").unwrap();
        fault::force_for_tests(Some(plan));
        let cache = Arc::new(ExpertCache::from_store(store.clone(), 2 * ONE_EXPERT).unwrap());
        std::thread::scope(|s| {
            for t in 0..clients {
                let cache = Arc::clone(&cache);
                let blocks = blocks.clone();
                s.spawn(move || {
                    for i in 0..24usize {
                        let block = blocks[(t + i) % blocks.len()];
                        let slot = (t * 3 + i) % 4;
                        let serve = cache
                            .try_serve(block, slot, 1 + i % 3)
                            .expect("centered store serves never error");
                        // Whatever tier answered, it answered.
                        let _ = serve_kind(&serve);
                    }
                });
            }
        });
        assert_eq!(cache.debug_flight_count(), 0, "{clients} clients leaked a flight");
        let m = cache.metrics();
        assert!(m.transient_errors > 0, "{clients} clients: storm never fired: {m:?}");
        fault::force_for_tests(None);
    }
}

/// A failing prefetch is advisory: it counts `prefetch_errors`, releases
/// its in-flight lease, and leaves the demand path able to fetch the very
/// same shard successfully — bit-identically to a never-prefetched run.
#[test]
fn failed_prefetch_never_poisons_demand_path() {
    let _guard = fault::test_serial();
    let art = pack_artifact(71, "prefetch", false);
    let store = Arc::new(ExpertStore::open(&art).unwrap());
    let bad = store.blocks()[0];
    let x = Matrix::from_fn(3, 16, |r, c| ((r + 2 * c) as f32 * 0.05).cos());

    fault::force_disabled_for_tests();
    let clean = ExpertCache::from_store(store.clone(), usize::MAX).unwrap();
    let want = clean.try_get(bad, 0).unwrap().forward(&x);

    // Exactly the first read of each target faults: the prefetch absorbs
    // the fault, the demand fetch right after succeeds first try.
    let plan = FaultPlan::parse("seed:3,spec:transient@store.read*1").unwrap();
    fault::force_for_tests(Some(plan));
    let cache = Arc::new(ExpertCache::from_store(store.clone(), usize::MAX).unwrap());
    let pf = Prefetcher::new(cache.clone(), store.clone());
    assert_eq!(pf.request(&[(bad, 0)]), 1, "one fetch scheduled");
    pf.quiesce();

    let m = cache.metrics();
    assert_eq!(m.prefetch_errors, 1, "the failed prefetch is counted: {m:?}");
    assert_eq!(cache.resident_shards(), 0, "nothing resident after the failure");
    assert_eq!(cache.debug_flight_count(), 0, "no lease leaked");

    let got = cache.try_get(bad, 0).unwrap().forward(&x);
    assert_eq!(got, want, "demand restore after failed prefetch must be exact");
    let m = cache.metrics();
    assert_eq!(m.fetch_retries, 0, "demand fetch succeeded on its first attempt");
    assert_eq!(m.transient_errors, 0, "prefetch errors are not demand transients");
    fault::force_for_tests(None);
}

// ----------------------------------------------- admission control (no store)

fn mem_engine(seed: u64) -> Engine {
    let m = tiny_model(seed);
    let mut rng = Rng::new(seed + 9);
    let cm = compress_model(&m, &ResMoE::up(), 0.25, 2, None, &mut rng);
    Engine::compressed(m, cm.layers, usize::MAX)
}

/// `max_queue = 1` with a single lingering worker: the first submit is
/// admitted, the burst behind it sheds typed `Overloaded` answers
/// immediately, and the shed counter records every one.
#[test]
fn queue_overflow_sheds_typed_responses() {
    let engine = mem_engine(81);
    let server = Server::start(
        engine,
        ServerConfig {
            batch_max: 8,
            batch_wait_us: 30_000, // linger >> the submit burst below
            workers: 1,
            max_queue: 1,
            ..Default::default()
        },
    );
    let n = 6;
    let rxs: Vec<_> = (0..n)
        .map(|i| {
            server.submit(Request::Score {
                tokens: (0..6).map(|t| ((t + i) % 32) as u32).collect(),
            })
        })
        .collect();
    let answers: Vec<Response> =
        rxs.into_iter().map(|rx| rx.recv().unwrap().0).collect();
    assert!(
        matches!(answers[0], Response::Score(_)),
        "the admitted request executes: {:?}",
        answers[0]
    );
    for (i, a) in answers.iter().enumerate().skip(1) {
        match a {
            Response::Overloaded(msg) => {
                assert!(msg.contains("queue full"), "request {i}: {msg}")
            }
            other => panic!("request {i} must shed, got {other:?}"),
        }
    }
    let m = server.shutdown();
    assert_eq!(m.shed, (n - 1) as u64, "every shed is counted");
}

/// Per-request deadlines: jobs that outlive `deadline_ms` while waiting
/// for their window are shed before execution — none of them run.
#[test]
fn expired_deadlines_shed_before_execution() {
    let engine = mem_engine(91);
    let server = Server::start(
        engine,
        ServerConfig {
            batch_max: 8,
            batch_wait_us: 30_000, // the window lingers ~30ms...
            workers: 1,
            deadline_ms: 5, // ...which blows every 5ms deadline
            ..Default::default()
        },
    );
    let n = 4;
    let rxs: Vec<_> = (0..n)
        .map(|i| {
            server.submit(Request::Score {
                tokens: (0..6).map(|t| ((t + 2 * i) % 32) as u32).collect(),
            })
        })
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        match rx.recv().unwrap().0 {
            Response::Overloaded(msg) => {
                assert!(msg.contains("deadline exceeded"), "request {i}: {msg}")
            }
            other => panic!("request {i} must miss its deadline, got {other:?}"),
        }
    }
    let m = server.shutdown();
    assert_eq!(m.shed, n as u64);
    assert_eq!(m.requests, 0, "no deadline-expired request may execute");
}
