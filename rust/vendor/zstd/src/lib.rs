//! Minimal in-tree stand-in for the `zstd` crate (offline build).
//!
//! Exposes the two functions the repo uses — [`encode_all`] / [`decode_all`]
//! — backed by an order-0 canonical-Huffman byte coder instead of real
//! zstd. That is enough for the checkpoint use case: f32 weight blobs have
//! near-constant exponent bytes and a JSON header, so entropy coding
//! shrinks them losslessly (typically 10–25 %). The container format is our
//! own (`RZH1` magic); it is NOT zstd-compatible on disk, which is fine
//! because this repo is the only reader and writer.

use std::io::{Error, ErrorKind, Read, Result};

const MAGIC: &[u8; 4] = b"RZH1";
/// Cap on canonical code length. Huffman depth is bounded by
/// log_phi(total_count) ≈ 1.44·log2(total), far below 64 for any input that
/// fits in memory; the cap is asserted, not enforced by reshaping.
const MAX_LEN: usize = 63;

/// Compress everything readable from `source`. `level` is accepted for API
/// compatibility and ignored (the coder has no quality knob).
pub fn encode_all<R: Read>(mut source: R, _level: i32) -> Result<Vec<u8>> {
    let mut data = Vec::new();
    source.read_to_end(&mut data)?;

    let mut out = Vec::with_capacity(data.len() / 2 + 512);
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&(data.len() as u64).to_le_bytes());
    if data.is_empty() {
        out.extend_from_slice(&[0u8; 256]);
        return Ok(out);
    }

    let mut freq = [0u64; 256];
    for &b in &data {
        freq[b as usize] += 1;
    }
    let lengths = huffman_lengths(&freq);
    out.extend_from_slice(&lengths);

    let codes = canonical_codes(&lengths);
    let mut bits = BitWriter::new();
    for &b in &data {
        let (code, len) = codes[b as usize];
        bits.push(code, len);
    }
    out.extend_from_slice(&bits.finish());
    Ok(out)
}

/// Decompress a buffer produced by [`encode_all`].
pub fn decode_all<R: Read>(mut source: R) -> Result<Vec<u8>> {
    let mut buf = Vec::new();
    source.read_to_end(&mut buf)?;
    let bad = |msg: &str| Error::new(ErrorKind::InvalidData, format!("rzh1: {msg}"));
    if buf.len() < 4 + 8 + 256 || &buf[..4] != MAGIC {
        return Err(bad("bad magic or truncated header"));
    }
    let raw_len = u64::from_le_bytes(buf[4..12].try_into().unwrap()) as usize;
    if raw_len == 0 {
        return Ok(Vec::new());
    }
    let mut lengths = [0u8; 256];
    lengths.copy_from_slice(&buf[12..268]);
    let payload = &buf[268..];

    // Canonical decode tables: per-length first code and symbol list.
    let mut count = [0usize; MAX_LEN + 1];
    let mut by_len: Vec<Vec<u8>> = vec![Vec::new(); MAX_LEN + 1];
    for sym in 0..256usize {
        let l = lengths[sym] as usize;
        if l > 0 {
            if l > MAX_LEN {
                return Err(bad("code length out of range"));
            }
            count[l] += 1;
            by_len[l].push(sym as u8);
        }
    }
    if count.iter().sum::<usize>() == 0 {
        return Err(bad("no symbols in table"));
    }
    // first[l] = smallest code of length l (same recurrence the encoder's
    // `canonical_codes` uses).
    let mut first = [0u64; MAX_LEN + 1];
    for l in 2..=MAX_LEN {
        first[l] = (first[l - 1] + count[l - 1] as u64) << 1;
    }

    let mut out = Vec::with_capacity(raw_len);
    let mut code = 0u64;
    let mut len = 0usize;
    'outer: for &byte in payload {
        for bit in (0..8).rev() {
            code = (code << 1) | ((byte >> bit) & 1) as u64;
            len += 1;
            if len > MAX_LEN {
                return Err(bad("code runs past max length"));
            }
            if count[len] > 0 {
                // Complete canonical codes of length `len` occupy exactly
                // [first[len], first[len] + count[len]); prefixes of longer
                // codes sort above that window.
                let offset = code.wrapping_sub(first[len]);
                if offset < count[len] as u64 {
                    out.push(by_len[len][offset as usize]);
                    if out.len() == raw_len {
                        break 'outer;
                    }
                    code = 0;
                    len = 0;
                }
            }
        }
    }
    if out.len() != raw_len {
        return Err(bad("truncated payload"));
    }
    Ok(out)
}

/// Huffman code lengths for the given byte frequencies (0 for unused).
fn huffman_lengths(freq: &[u64; 256]) -> [u8; 256] {
    let mut lengths = [0u8; 256];
    // Arena of (weight, parent); leaves first.
    let mut weight: Vec<u64> = Vec::new();
    let mut parent: Vec<usize> = Vec::new();
    let mut leaf_of_sym = [usize::MAX; 256];
    let mut heap = std::collections::BinaryHeap::new();
    for sym in 0..256usize {
        if freq[sym] > 0 {
            let id = weight.len();
            leaf_of_sym[sym] = id;
            weight.push(freq[sym]);
            parent.push(usize::MAX);
            heap.push(std::cmp::Reverse((freq[sym], id)));
        }
    }
    if heap.len() == 1 {
        // Single distinct byte: give it a 1-bit code.
        for sym in 0..256usize {
            if leaf_of_sym[sym] != usize::MAX {
                lengths[sym] = 1;
            }
        }
        return lengths;
    }
    while heap.len() > 1 {
        let std::cmp::Reverse((w1, i1)) = heap.pop().unwrap();
        let std::cmp::Reverse((w2, i2)) = heap.pop().unwrap();
        let id = weight.len();
        weight.push(w1 + w2);
        parent.push(usize::MAX);
        parent[i1] = id;
        parent[i2] = id;
        heap.push(std::cmp::Reverse((w1 + w2, id)));
    }
    for sym in 0..256usize {
        let mut node = leaf_of_sym[sym];
        if node == usize::MAX {
            continue;
        }
        let mut depth = 0u8;
        while parent[node] != usize::MAX {
            node = parent[node];
            depth += 1;
        }
        assert!((depth as usize) <= MAX_LEN, "huffman depth {depth} exceeds cap");
        lengths[sym] = depth;
    }
    lengths
}

/// Canonical (code, length) per symbol: symbols sorted by (length, symbol)
/// get consecutive codes, lengths bump with a left shift — the scheme the
/// decoder's `first[]` table mirrors exactly.
fn canonical_codes(lengths: &[u8; 256]) -> [(u64, u8); 256] {
    let mut count = [0u64; MAX_LEN + 1];
    for &l in lengths.iter() {
        if l > 0 {
            count[l as usize] += 1;
        }
    }
    let mut next = [0u64; MAX_LEN + 1];
    let mut code = 0u64;
    for l in 1..=MAX_LEN {
        code = (code + count[l - 1]) << 1;
        next[l] = code;
    }
    let mut codes = [(0u64, 0u8); 256];
    for sym in 0..256usize {
        let l = lengths[sym];
        if l > 0 {
            codes[sym] = (next[l as usize], l);
            next[l as usize] += 1;
        }
    }
    codes
}

/// MSB-first bit accumulator.
struct BitWriter {
    bytes: Vec<u8>,
    cur: u8,
    used: u8,
}

impl BitWriter {
    fn new() -> BitWriter {
        BitWriter { bytes: Vec::new(), cur: 0, used: 0 }
    }

    fn push(&mut self, code: u64, len: u8) {
        for bit in (0..len).rev() {
            self.cur = (self.cur << 1) | ((code >> bit) & 1) as u8;
            self.used += 1;
            if self.used == 8 {
                self.bytes.push(self.cur);
                self.cur = 0;
                self.used = 0;
            }
        }
    }

    fn finish(mut self) -> Vec<u8> {
        if self.used > 0 {
            self.cur <<= 8 - self.used;
            self.bytes.push(self.cur);
        }
        self.bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(data: &[u8]) -> Vec<u8> {
        let enc = encode_all(data, 3).unwrap();
        decode_all(&enc[..]).unwrap()
    }

    #[test]
    fn roundtrip_empty_and_tiny() {
        assert_eq!(roundtrip(b""), b"");
        assert_eq!(roundtrip(b"a"), b"a");
        assert_eq!(roundtrip(b"aaaaaaaa"), b"aaaaaaaa");
        assert_eq!(roundtrip(b"ab"), b"ab");
    }

    #[test]
    fn roundtrip_all_bytes() {
        let data: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        assert_eq!(roundtrip(&data), data);
    }

    #[test]
    fn roundtrip_pseudorandom() {
        // xorshift stream — near-incompressible, exercises long codes.
        let mut x = 0x9E3779B97F4A7C15u64;
        let data: Vec<u8> = (0..50_000)
            .map(|_| {
                x ^= x << 13;
                x ^= x >> 7;
                x ^= x << 17;
                (x & 0xFF) as u8
            })
            .collect();
        assert_eq!(roundtrip(&data), data);
    }

    #[test]
    fn biased_data_shrinks() {
        // 75 % of bytes drawn from a 4-symbol alphabet — the f32-exponent
        // pattern the checkpoint writer relies on.
        let mut x = 1u64;
        let data: Vec<u8> = (0..100_000)
            .map(|i| {
                x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
                if i % 4 == 3 {
                    0x3C + ((x >> 33) & 1) as u8
                } else {
                    (x >> 40) as u8
                }
            })
            .collect();
        let enc = encode_all(&data[..], 3).unwrap();
        assert!(enc.len() < data.len(), "{} !< {}", enc.len(), data.len());
        assert_eq!(decode_all(&enc[..]).unwrap(), data);
    }

    #[test]
    fn rejects_garbage() {
        assert!(decode_all(&b"NOPE"[..]).is_err());
        assert!(decode_all(&[0u8; 300][..]).is_err());
    }
}
