//! Minimal in-tree stand-in for the `anyhow` crate.
//!
//! The offline build environment has no crates.io access, so this path
//! crate provides the subset of the real API the repo uses: a string-backed
//! [`Error`], `Result<T>`, the `anyhow!` / `bail!` / `ensure!` macros, and
//! the [`Context`] extension trait for `Result` and `Option`. Error chains
//! are flattened into one `context: source` message at attach time, which
//! matches how this repo formats errors (`{e}` / `{e:#}`).

use std::fmt;

/// A string-backed error value. Like the real `anyhow::Error` it does NOT
/// implement `std::error::Error` (that is what makes the blanket `From`
/// conversion below coherent).
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable (the `anyhow!` macro target).
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error { msg: msg.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{:#}` prints the full chain in real anyhow; our chain is already
        // flattened, so both forms print the same string.
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

mod private {
    /// The conversion hook behind [`crate::Context`]: implemented for every
    /// `std::error::Error` AND for [`crate::Error`] itself, mirroring the
    /// real anyhow's private `ext::StdError` trait.
    pub trait IntoError {
        fn into_error(self) -> crate::Error;
    }

    impl<E> IntoError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn into_error(self) -> crate::Error {
            crate::Error::msg(self)
        }
    }

    impl IntoError for crate::Error {
        fn into_error(self) -> crate::Error {
            self
        }
    }
}

/// `.context(..)` / `.with_context(..)` on `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: private::IntoError> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {}", e.into_error())))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {}", f(), e.into_error())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn macro_and_display() {
        let e = anyhow!("bad thing {}", 7);
        assert_eq!(e.to_string(), "bad thing 7");
        assert_eq!(format!("{e:#}"), "bad thing 7");
        assert_eq!(format!("{e:?}"), "bad thing 7");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(f().unwrap_err().to_string().contains("gone"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening file").unwrap_err();
        assert_eq!(e.to_string(), "opening file: gone");

        let r2: Result<()> = Err(anyhow!("inner"));
        let e2 = r2.with_context(|| "outer").unwrap_err();
        assert_eq!(e2.to_string(), "outer: inner");

        let n: Option<u32> = None;
        assert_eq!(n.context("missing").unwrap_err().to_string(), "missing");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x > 2, "too small: {x}");
            if x > 100 {
                bail!("too big");
            }
            Ok(x)
        }
        assert_eq!(f(1).unwrap_err().to_string(), "too small: 1");
        assert_eq!(f(101).unwrap_err().to_string(), "too big");
        assert_eq!(f(10).unwrap(), 10);
    }
}
