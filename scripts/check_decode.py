#!/usr/bin/env python3
"""Decode-lane CI gate: throughput + relaxed-parity pins over a
`BENCH_decode.json` document (from `scripts/sim_decode.py`, or a future
engine-backed decode bench emitting the same shape).

Gates:

1. **Throughput** — batched decode tok/s >= `RESMOE_DECODE_SPEEDUP`
   (default 2.0) x the sequential lane at the document's client count,
   and the mean step batch actually exceeds 1 (batching happened).
2. **Relaxed parity** — bit-identical greedy sequences in both
   order-independent regimes (roomy = all-restore, zero = all-fused),
   and the max per-token logit relative error against the sequential
   reference stays under `RESMOE_DECODE_RELERR` (default 0.05) AND under
   the document's own fused-approximation bound.
3. **Conservation** — zero scheduler bookkeeping violations; KV page
   pool drains (granted == released, used == 0) in the roomy run and
   under refusals in the tight run.

Writes gate outcomes merged into `reports/BENCH_decode.json`. Exits
non-zero on any failed gate.

Usage: check_decode.py BENCH_DECODE_JSON
"""

import sys

from gatelib import GateSet, env_f, load_json


def main():
    if len(sys.argv) != 2:
        sys.exit(f"usage: {sys.argv[0]} BENCH_DECODE_JSON")
    doc = load_json(sys.argv[1])

    gates = GateSet("check_decode")
    gate = gates.gate

    gate("document is a decode bench", doc.get("bench") == "decode",
         f"bench={doc.get('bench')} source={doc.get('source')}")

    speedup_min = env_f("RESMOE_DECODE_SPEEDUP", 2.0)
    relerr_max = env_f("RESMOE_DECODE_RELERR", 0.05)

    seq, bat = doc.get("sequential", {}), doc.get("batched", {})
    gate(f"batched >= {speedup_min:g}x sequential tok/s "
         f"at {doc.get('clients')} clients",
         doc.get("speedup", 0.0) >= speedup_min,
         f"{bat.get('tok_s', 0):.0f} vs {seq.get('tok_s', 0):.0f} tok/s "
         f"({doc.get('speedup', 0.0):.2f}x)")
    gate("decode steps actually batch",
         bat.get("mean_step_batch", 0.0) > 1.0,
         f"mean step batch {bat.get('mean_step_batch', 0.0):.2f}")

    p = doc.get("parity", {})
    for regime in ("roomy", "zero"):
        gate(f"{regime} budget greedy sequences bit-identical",
             p.get(f"greedy_match_{regime}") is True,
             f"greedy_match_{regime}={p.get(f'greedy_match_{regime}')}")
    bound = min(relerr_max, p.get("rel_err_bound", relerr_max))
    gate(f"per-token logit rel-err <= {bound:.2e}",
         p.get("max_rel_err", float("inf")) <= bound,
         f"max {p.get('max_rel_err', float('inf')):.2e} over "
         f"{p.get('rows_compared', 0)} rows")

    s = doc.get("scheduler", {})
    gate("scheduler bookkeeping conserves",
         s.get("violations") == 0 and s.get("traces", 0) > 0,
         f"{s.get('violations')} violation(s) over {s.get('traces')} traces")
    for label in ("kv_pool", "kv_pool_tight"):
        kp = doc.get(label, {})
        gate(f"{label} conserves",
             kp.get("conserved") is True
             and kp.get("used") == 0
             and kp.get("granted") == kp.get("released"),
             f"granted {kp.get('granted')} released {kp.get('released')} "
             f"used {kp.get('used')} refusals {kp.get('refusals')}")
    gate("tight pool exercises the refusal path",
         doc.get("kv_pool_tight", {}).get("refusals", 0) > 0,
         f"{doc.get('kv_pool_tight', {}).get('refusals', 0)} refusal(s)")

    report = dict(doc)
    report["gates"] = {"speedup_min": speedup_min, "relerr_max": relerr_max}
    gates.write_report("decode", report)
    gates.finish()


if __name__ == "__main__":
    main()
