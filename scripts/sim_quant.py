#!/usr/bin/env python3
"""Seeded validation harness for PR 6 (int8 quantized residual shards).

The container has no Rust toolchain, so this script validates the
load-bearing claims of `rust/src/tensor/quant.rs`, the dequant-fused
kernels, and the RMES v2 container against faithful Python ports in exact
float32 arithmetic:

1. **Symmetric int8 roundtrip** — per-row scale `s = absmax/127`, code
   `round_half_away(v/s)` clamped to ±127 (Rust `f32::round` is
   half-AWAY-from-zero; numpy's `rint` is half-even, so the sim emulates
   `sign(x)·floor(|x|+0.5)`): the dequantized matrix must sit within the
   advertised per-row bound `0.5·max_scale·(1+1e-3)`, zero rows must
   roundtrip exactly, and int8+scales bytes must be ≤ 0.35× the f32 bytes
   at expert shapes.

2. **Dequant-fused == dequant-then-GEMM, bit for bit** — the fused kernels
   compute `dq = f32(code)·scale` per element and then run the exact FMA
   fold of their kernel kind; replaying the fold with inline dequant vs a
   pre-materialized dequant array must agree in raw f32 bits (uint32 view),
   including the KC k-panel split and CSR folds.

3. **RMES v2 container** — version-2 header + `"version":2` JSON index
   with per-shard CRC-32-of-compressed-bytes: roundtrip, any single bit
   flip in a shard detected, v1 files accepted read-only, header/index
   version disagreement rejected, and v1 files claiming `q8-*` shard kinds
   rejected (quantized kinds are a v2 feature).
"""

import json
import struct
import zlib

import numpy as np

f32 = np.float32
f64 = np.float64

KC = 256  # k-panel depth shared with the f32 GEMM driver


def fma(a, b, c):
    """round_f32(a*b + c): f32 FMA emulated via f64 (product is exact)."""
    return f32(f64(a) * f64(b) + f64(c))


# ------------------------------------------------- 1. int8 quantization

SLACK = f32(1.0 + 1e-3)


def round_half_away(x):
    """Rust f32::round semantics (ties away from zero; numpy rint is
    half-even and WOULD differ at exact .5 code boundaries)."""
    return np.sign(x) * np.floor(np.abs(x) + f32(0.5))


def quantize_rows(m):
    rows, _ = m.shape
    scales = np.zeros(rows, dtype=f32)
    codes = np.zeros(m.shape, dtype=np.int8)
    for r in range(rows):
        absmax = f32(np.max(np.abs(m[r]))) if m.shape[1] else f32(0.0)
        if absmax == 0.0:
            continue
        s = f32(absmax / f32(127.0))
        scales[r] = s
        q = round_half_away(f32(m[r] / s))
        codes[r] = np.clip(q, -127, 127).astype(np.int8)
    return codes, scales


def dequant(codes, scales):
    return f32(codes.astype(f32) * scales[:, None])


def check_roundtrip():
    rng = np.random.default_rng(0x178)
    for rows, cols in [(1, 1), (7, 13), (16, 64), (96, 224), (33, 5)]:
        m = f32(rng.standard_normal((rows, cols)) * 1.5)
        codes, scales = quantize_rows(m)
        back = dequant(codes, scales)
        bound = f32(0.5) * scales.max() * SLACK
        worst = np.max(np.abs(m.astype(f64) - back.astype(f64)))
        assert worst <= bound, f"{rows}x{cols}: err {worst} > bound {bound}"
        # Per-row: the row's own scale bounds its own error.
        for r in range(rows):
            rowerr = np.max(np.abs(m[r].astype(f64) - back[r].astype(f64)))
            assert rowerr <= f32(0.5) * scales[r] * SLACK + 1e-12
        # Byte criterion holds at expert shapes; skinny rows (cols < 16)
        # are dominated by the per-row scale and are excluded, matching
        # the PackSummary acceptance note.
        if cols >= 16:
            int8_bytes = codes.size + rows * 4
            assert int8_bytes <= 0.35 * m.size * 4, \
                f"{rows}x{cols}: int8 bytes {int8_bytes} not ≤ 0.35× f32"
    # Zero rows: scale 0, codes 0, exact roundtrip.
    z = np.zeros((3, 8), dtype=f32)
    codes, scales = quantize_rows(z)
    assert (scales == 0).all() and (codes == 0).all()
    assert (dequant(codes, scales) == z).all()
    # Codes never exceed ±127 even at the absmax element (v/s == 127.0
    # exactly when v == absmax only if the division is exact; the clamp
    # covers the rounded-up case).
    spike = f32(np.array([[1.0, -3.3, 3.3]]))
    codes, _ = quantize_rows(spike)
    assert codes.max() <= 127 and codes.min() >= -127
    print("  [1] int8 roundtrip within 0.5·scale·slack; zero rows exact; "
          "int8 bytes ≤ 0.35× f32 at expert shapes")


# ------------------------- 2. dequant-fused == dequant-then-GEMM, bitwise


def qgemm_nt_fused(x, codes, scales):
    """Inline-dequant replay of the fused NT fold: each B element is
    dequantized (one f32 multiply) inside the k-panel FMA chain."""
    m, k = x.shape
    n = codes.shape[0]
    c = np.zeros((m, n), dtype=f32)
    for i in range(m):
        for j in range(n):
            total = f32(0.0)
            for kb in range(0, max(k, 1), KC):
                kw = min(KC, k - kb)
                acc = f32(0.0)
                for kk in range(kw):
                    dq = f32(f32(codes[j, kb + kk]) * scales[j])
                    acc = fma(x[i, kb + kk], dq, acc)
                total = f32(total + acc)
            c[i, j] = total
    return c


def gemm_nt_materialized(x, bt):
    """The dequant-THEN-GEMM reference: identical fold over a
    pre-materialized f32 matrix (sim_simd.py's gemm_nt_sim)."""
    m, k = x.shape
    n = bt.shape[0]
    c = np.zeros((m, n), dtype=f32)
    for i in range(m):
        for j in range(n):
            total = f32(0.0)
            for kb in range(0, max(k, 1), KC):
                kw = min(KC, k - kb)
                acc = f32(0.0)
                for kk in range(kw):
                    acc = fma(x[i, kb + kk], bt[j, kb + kk], acc)
                total = f32(total + acc)
            c[i, j] = total
    return c


def check_fused_bitwise():
    rng = np.random.default_rng(0x179)
    for m, n, k in [(1, 1, 1), (5, 17, 31), (6, 16, 300), (9, 40, 257)]:
        w = f32(rng.standard_normal((n, k)))
        codes, scales = quantize_rows(w)
        x = f32(rng.standard_normal((m, k)))
        fused = qgemm_nt_fused(x, codes, scales)
        two_step = gemm_nt_materialized(x, dequant(codes, scales))
        assert (fused.view(np.uint32) == two_step.view(np.uint32)).all(), \
            f"fused != dequant-then-GEMM at {m}x{k}@{n}"
        # And the fused output tracks the unquantized product within the
        # propagated bound ‖x‖₁-style envelope (loose sanity check).
        want = x.astype(f64) @ w.astype(f64).T
        err = np.max(np.abs(fused.astype(f64) - want))
        envelope = 0.5 * scales.max() * SLACK * np.max(
            np.sum(np.abs(x.astype(f64)), axis=1)) + 1e-3
        assert err <= envelope, f"{m}x{k}@{n}: err {err} > envelope {envelope}"
    # CSR fold: inline dequant per stored value, strict index order.
    dense = f32(rng.standard_normal((12, 10)))
    dense[f32(rng.random((12, 10))) > 0.35] = 0
    codes, scales = quantize_rows(dense)
    codes[dense == 0] = 0
    x = f32(rng.standard_normal((4, 10)))
    out_fused = np.zeros((4, 12), dtype=f32)
    out_two = np.zeros((4, 12), dtype=f32)
    dq = dequant(codes, scales)
    for bi in range(4):
        for r in range(12):
            accf = f32(0.0)
            acct = f32(0.0)
            nz = False
            for c in range(10):
                if dense[r, c] != 0:
                    nz = True
                    inline = f32(f32(codes[r, c]) * scales[r])
                    accf = fma(inline, x[bi, c], accf)
                    acct = fma(dq[r, c], x[bi, c], acct)
            if nz:
                out_fused[bi, r] = f32(out_fused[bi, r] + accf)
                out_two[bi, r] = f32(out_two[bi, r] + acct)
    assert (out_fused.view(np.uint32) == out_two.view(np.uint32)).all()
    print("  [2] dequant-fused GEMM/SpMM folds bitwise-equal to "
          "dequant-then-GEMM across k-panel and ragged shapes")


# ------------------------------------------------- 3. RMES v2 container

MAGIC = b"RMES"
DATA_START = 16


def pack_store(shards, version=2, kinds=None):
    """Minimal RMES replica: header, zstd-stand-in (zlib) shards with
    CRC-32 of the COMPRESSED bytes, JSON index last."""
    blob = bytearray(b"\0" * DATA_START)
    entries = []
    for i, payload in enumerate(shards):
        comp = zlib.compress(payload, 3)
        entries.append({"offset": len(blob), "bytes": len(comp),
                        "crc": zlib.crc32(comp) & 0xFFFFFFFF,
                        "kind": (kinds or ["csr"] * len(shards))[i]})
        blob += comp
    index_off = len(blob)
    blob += json.dumps({"shards": entries, "version": version},
                       separators=(",", ":")).encode()
    blob[0:4] = MAGIC
    blob[4:8] = struct.pack("<I", version)
    blob[8:16] = struct.pack("<Q", index_off)
    return bytes(blob)


def open_store(blob, store_version=2, min_version=1):
    """Replays format.rs `open`: magic, version window, index parse,
    header/index cross-check, v1-claiming-q8 rejection."""
    assert blob[0:4] == MAGIC, "bad magic"
    version = struct.unpack("<I", blob[4:8])[0]
    if not (min_version <= version <= store_version):
        raise ValueError(f"unsupported store version {version}")
    index_off = struct.unpack("<Q", blob[8:16])[0]
    index = json.loads(blob[index_off:].decode())
    if index["version"] != version:
        raise ValueError("header version disagrees with index version")
    for e in index["shards"]:
        if version < 2 and e["kind"].startswith("q8-"):
            raise ValueError(f"v{version} store contains quantized shard "
                             f"kind '{e['kind']}'")
    return version, index


def load_shard(blob, entry):
    comp = blob[entry["offset"]:entry["offset"] + entry["bytes"]]
    if (zlib.crc32(comp) & 0xFFFFFFFF) != entry["crc"]:
        raise ValueError("shard checksum mismatch")
    return zlib.decompress(comp)


def check_container():
    rng = np.random.default_rng(0x180)
    shards = [rng.integers(0, 256, size=200, dtype=np.uint8).tobytes()
              for _ in range(3)]
    blob = pack_store(shards, kinds=["q8-csr", "csr", "q8-dense"])
    version, index = open_store(blob)
    assert version == 2
    for payload, entry in zip(shards, index["shards"]):
        assert load_shard(blob, entry) == payload
    # Any single bit flip inside a shard is caught by its CRC.
    flips = 0
    for _ in range(32):
        e = index["shards"][rng.integers(0, 3)]
        pos = e["offset"] + int(rng.integers(0, e["bytes"]))
        bad = bytearray(blob)
        bad[pos] ^= 1 << int(rng.integers(0, 8))
        try:
            load_shard(bytes(bad), e)
        except ValueError:
            flips += 1
    assert flips == 32, f"only {flips}/32 bit flips detected"
    # v1 files (f32 kinds only) read back cleanly.
    v1 = pack_store(shards[:2], version=1, kinds=["csr", "svd"])
    assert open_store(v1)[0] == 1
    # Future versions and header/index disagreement are rejected.
    for bad_blob in [pack_store(shards, version=3),
                     pack_store(shards, version=2)[:4] +
                     struct.pack("<I", 1) + pack_store(shards, version=2)[8:]]:
        try:
            open_store(bad_blob)
            raise AssertionError("bad container accepted")
        except ValueError:
            pass
    # A v1 file claiming quantized shard kinds is rejected.
    v1q = pack_store(shards, version=1, kinds=["q8-csr", "csr", "csr"])
    try:
        open_store(v1q)
        raise AssertionError("v1 + q8-* kinds accepted")
    except ValueError as e:
        assert "quantized" in str(e)
    print("  [3] RMES v2 replica: roundtrip, 32/32 bit flips caught, v1 "
          "read-back, version cross-check, v1+q8 rejected")


def main():
    print("sim_quant: validating int8 residual tier (no-toolchain fallback)")
    check_roundtrip()
    check_fused_bitwise()
    check_container()
    print("sim_quant OK")


if __name__ == "__main__":
    main()
