#!/usr/bin/env python3
"""Regenerate BENCHMARKS.md — the trend page summarizing every
`reports/BENCH_*.json` document the bench/gate tooling produces.

Three document shapes are understood:

* **scenario benches** (`"bench": "scenarios"`, from `loadgen`/
  `check_scenarios.py`/`sim_loadgen.py`) — rendered as the per-scenario
  traffic table (latencies, sheds, cache decisions, fingerprints).
* **gate outcomes** (any document with `"pass"`/`"failures"`, from the
  `check_*.py` gates) — rendered as a status line plus failure list.
* **bench tables** (`"title"`/`"header"`/`"rows"`, from
  `Table::save_json` in `rust/src/util/bench.rs`) — rendered verbatim as
  markdown tables.

Anything else falls back to a top-level scalar dump. Graceful when
`reports/` is empty or absent: the page then just says how to populate
it. Deterministic: files are processed in sorted order and nothing
timestamps the output.

The page also carries a **Trends** section diffing key metrics across
commits: every run appends (or, for a repeated commit, replaces) an
entry in `reports/history.json` keyed by `git rev-parse --short HEAD`,
and the table shows the last few commits side by side with a delta
column against the previous one. `--no-history` renders without touching
the history file (for read-only inspection).

Usage: benchmarks_md.py [--out BENCHMARKS.md] [--no-history]
"""

import glob
import json
import os
import subprocess
import sys

HISTORY_PATH = os.path.join("reports", "history.json")
HISTORY_KEEP = 20  # entries retained (one per distinct commit run)
TREND_COLS = 5  # commits shown side by side in the Trends table


def fmt(v, unit=""):
    if v is None:
        return "-"
    if isinstance(v, bool):
        return str(v).lower()
    if isinstance(v, float):
        return f"{v:.2f}{unit}"
    return f"{v}{unit}"


def md_table(header, rows):
    out = ["| " + " | ".join(header) + " |",
           "|" + "|".join("---" for _ in header) + "|"]
    out += ["| " + " | ".join(str(c) for c in row) + " |" for row in rows]
    return out


def render_scenarios(doc):
    lines = [f"Source `{doc.get('source')}`, seed {doc.get('seed')}, "
             f"kernel `{fmt(doc.get('kernel'))}`."
             + (" Engine-only columns are `-` for the python-sim replica."
                if doc.get("source") == "python-sim" else ""), ""]
    rows = []
    for s in doc.get("scenarios", []):
        v = s.get("virtual", {})
        c = s.get("cache") or {}
        k = s.get("skew") or {}
        rows.append([
            s["scenario"],
            s["arrivals"],
            s["executed"],
            f"{s['shed_admission']}/{s['shed_deadline']}",
            fmt(v.get("p50_ms"), " ms"),
            fmt(v.get("p99_ms"), " ms"),
            fmt(v.get("ttft_p99_ms"), " ms"),
            fmt(v.get("tok_s")),
            v.get("windows", "-"),
            fmt(c.get("hit_rate")),
            fmt(c.get("quant_promotions")),
            fmt(k.get("ratio"), "x"),
            s["fingerprints"]["schedule"],
        ])
    lines += md_table(
        ["scenario", "arrivals", "executed", "shed adm/ddl", "p50", "p99",
         "ttft p99", "tok/s (virtual)", "windows", "hit rate",
         "quant promos", "skew", "schedule fp"],
        rows)
    if "pass" in doc:
        lines += ["", gate_status(doc)]
    return lines


def gate_status(doc):
    if doc.get("pass"):
        return "Gates: **PASS**"
    fails = "; ".join(doc.get("failures", [])) or "unknown"
    return f"Gates: **FAIL** — {fails}"


def render_gates(doc):
    lines = [gate_status(doc), ""]
    skip = {"bench", "gates", "failures", "pass", "scenarios", "snapshot"}
    scalars = [(k, v) for k, v in doc.items()
               if k not in skip and isinstance(v, (int, float, str, bool))]
    if scalars:
        lines += md_table(["metric", "value"],
                          [[k, fmt(v)] for k, v in sorted(scalars)])
    return lines


def render_table(doc):
    return [f"**{doc['title']}**", ""] + md_table(doc["header"], doc["rows"])


def render_generic(doc):
    scalars = [(k, v) for k, v in doc.items()
               if isinstance(v, (int, float, str, bool))]
    return md_table(["field", "value"], [[k, fmt(v)] for k, v in sorted(scalars)])


# ------------------------------------------------------------------ trends


def trend_metrics(stem, doc):
    """The flat scalar metrics one report contributes to the cross-commit
    trend table, keyed `<stem>.<metric>`."""
    m = {}
    if doc.get("bench") == "scenarios":
        for s in doc.get("scenarios", []):
            tok = (s.get("virtual") or {}).get("tok_s")
            if tok is not None:
                m[f"{s['scenario']}.tok_s"] = round(tok, 1)
    elif doc.get("bench") == "decode":
        if doc.get("speedup") is not None:
            m["speedup"] = round(doc["speedup"], 2)
        for lane in ("sequential", "batched"):
            tok = (doc.get(lane) or {}).get("tok_s")
            if tok is not None:
                m[f"{lane}.tok_s"] = round(tok, 1)
        err = (doc.get("parity") or {}).get("max_rel_err")
        if err is not None:
            m["max_rel_err"] = float(f"{err:.2e}")
    if "pass" in doc:
        m["pass"] = bool(doc["pass"])
    return {f"{stem}.{k}": v for k, v in m.items()}


def git_head():
    try:
        out = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             capture_output=True, text=True, timeout=10)
        head = out.stdout.strip()
        return head if out.returncode == 0 and head else None
    except OSError:
        return None


def update_history(metrics):
    """Append (or replace, for a re-run on the same commit) the current
    metrics under HEAD's short hash; returns the trimmed history."""
    commit = git_head() or "worktree"
    history = []
    if os.path.exists(HISTORY_PATH):
        try:
            with open(HISTORY_PATH) as f:
                history = json.load(f)
        except (OSError, json.JSONDecodeError):
            history = []
    history = [e for e in history
               if isinstance(e, dict) and "commit" in e and "metrics" in e]
    if history and history[-1]["commit"] == commit:
        history[-1]["metrics"] = metrics
    else:
        history.append({"commit": commit, "metrics": metrics})
    history = history[-HISTORY_KEEP:]
    os.makedirs("reports", exist_ok=True)
    with open(HISTORY_PATH, "w") as f:
        json.dump(history, f, indent=2)
        f.write("\n")
    return history


def delta(prev, cur):
    if (prev is None or cur is None
            or not isinstance(prev, (int, float)) or isinstance(prev, bool)
            or not isinstance(cur, (int, float)) or isinstance(cur, bool)):
        return "-"
    d = cur - prev
    if d == 0:
        return "0"
    pct = f" ({d / prev:+.1%})" if prev else ""
    return f"{d:+.3g}{pct}"


def render_trends(history):
    shown = history[-TREND_COLS:]
    lines = [f"Key metrics per commit (last {len(shown)} of {len(history)} "
             f"recorded in `reports/history.json`; delta is newest vs "
             f"previous).", ""]

    def tfmt(v):
        if v is None:
            return "-"
        if isinstance(v, bool):
            return str(v).lower()
        if isinstance(v, float):
            return f"{v:.4g}"
        return str(v)

    keys = sorted({k for e in shown for k in e["metrics"]})
    header = (["metric"] + [e["commit"] for e in shown]
              + (["delta"] if len(shown) > 1 else []))
    rows = []
    for k in keys:
        vals = [e["metrics"].get(k) for e in shown]
        row = [k] + [tfmt(v) for v in vals]
        if len(shown) > 1:
            row.append(delta(vals[-2], vals[-1]))
        rows.append(row)
    return lines + md_table(header, rows)


def main():
    out_path = "BENCHMARKS.md"
    with_history = True
    args = sys.argv[1:]
    while args:
        a = args.pop(0)
        if a == "--out":
            out_path = args.pop(0)
        elif a == "--no-history":
            with_history = False
        else:
            sys.exit(f"usage: {sys.argv[0]} [--out BENCHMARKS.md] "
                     "[--no-history]")

    paths = sorted(glob.glob(os.path.join("reports", "BENCH_*.json")))
    lines = [
        "# Benchmarks",
        "",
        "Generated by `scripts/benchmarks_md.py` from `reports/BENCH_*.json`",
        "(produced by `scripts/ci.sh`, `cargo bench`, and the `check_*.py`",
        "gates). Regenerate after any bench run; do not edit by hand.",
        "",
    ]
    if not paths:
        lines += ["No reports found. Run `scripts/ci.sh` (or "
                  "`python3 scripts/sim_loadgen.py` on a toolchain-less "
                  "host) to populate `reports/`.", ""]
    metrics = {}
    for path in paths:
        stem = os.path.basename(path)[len("BENCH_"):-len(".json")]
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            lines += [f"## {stem}", "", f"unreadable: {e}", ""]
            continue
        lines += [f"## {stem}", ""]
        if doc.get("bench") == "scenarios":
            lines += render_scenarios(doc)
        elif "title" in doc and "rows" in doc:
            lines += render_table(doc)
        elif "pass" in doc:
            lines += render_gates(doc)
        else:
            lines += render_generic(doc)
        lines += [""]
        metrics.update(trend_metrics(stem, doc))

    if with_history and metrics:
        history = update_history(metrics)
        lines += ["## Trends", ""] + render_trends(history) + [""]

    with open(out_path, "w") as f:
        f.write("\n".join(lines).rstrip() + "\n")
    print(f"benchmarks_md: wrote {out_path} ({len(paths)} report(s))")


if __name__ == "__main__":
    main()
