#!/usr/bin/env python3
"""Fault-tolerance CI gate: chaos-smoke convergence + fault-counter audit.

Consumes two `--metrics-out` documents from the packed serving demo — a
clean run (no `RESMOE_FAULTS`) and a chaos run under a *converging*
transient storm (`transient@store.read*2` vs the cache's 3-retry budget) —
and enforces:

1. **Clean baseline is fault-free** — every fault counter
   (`cache.transient_errors`, `cache.fetch_retries`,
   `cache.quarantined_shards`, `cache.degraded_serves`,
   `cache.prefetch_errors`, `server.shed`) is zero in the clean run: the
   disabled failpoint registry really is inert.
2. **The storm fired and was retried** — the chaos run shows
   `transient_errors > 0`; demand-path transients pair 1:1 with retries
   (`fetch_retries == transient_errors` net of prefetch-path errors,
   which are counted but never retried).
3. **The storm converged** — zero quarantines, zero degraded serves, and
   every request completed (`requests` matches the clean run; the demo
   itself already fails on any `Response::Error`).
4. **Tail latency survives the chaos** — chaos-run p99 within
   `RESMOE_FAULTS_P99_MS` (default: 4x the clean run's p99, floor 250 ms):
   backed-off retries may not blow up the tail.
5. **Schema parity** — both runs export identical instrument names:
   injecting faults must not change what is measured.

Writes retries/quarantines/degraded-rate/shed-rate/p99 for both runs to
`reports/BENCH_faults.json`. Exits non-zero on any failed gate.

Usage: check_faults.py CLEAN_METRICS_JSON CHAOS_METRICS_JSON
"""

import sys

from gatelib import GateSet, counters, env_f, load_json, snapshot_schema

FAULT_COUNTERS = (
    "cache.transient_errors",
    "cache.fetch_retries",
    "cache.quarantined_shards",
    "cache.degraded_serves",
    "cache.prefetch_errors",
    "server.shed",
)


def fault_view(doc):
    c = counters(doc)
    serves = c.get("cache.hits", 0) + c.get("cache.misses", 0)
    requests = doc["requests"]
    shed = c.get("server.shed", 0)
    return {
        "requests": requests,
        "p99_ms": doc["p99_ms"],
        "transient_errors": c.get("cache.transient_errors", 0),
        "fetch_retries": c.get("cache.fetch_retries", 0),
        "quarantined_shards": c.get("cache.quarantined_shards", 0),
        "degraded_serves": c.get("cache.degraded_serves", 0),
        "prefetch_errors": c.get("cache.prefetch_errors", 0),
        "shed": shed,
        "degraded_rate": c.get("cache.degraded_serves", 0) / serves if serves else 0.0,
        "shed_rate": shed / (requests + shed) if requests + shed else 0.0,
    }


def main():
    if len(sys.argv) != 3:
        sys.exit(f"usage: {sys.argv[0]} CLEAN_METRICS_JSON CHAOS_METRICS_JSON")
    clean = load_json(sys.argv[1])
    chaos = load_json(sys.argv[2])
    cv, xv = fault_view(clean), fault_view(chaos)

    gates = GateSet("check_faults")
    gate = gates.gate

    dirty = {k: counters(clean).get(k, 0) for k in FAULT_COUNTERS
             if counters(clean).get(k, 0)}
    gate("clean run is fault-free", not dirty, dirty or "all fault counters zero")

    gate("chaos storm fired", xv["transient_errors"] > 0,
         f"{xv['transient_errors']} injected transients")
    # Prefetch-path store errors are counted but never retried; demand-path
    # transients under a converging storm pair 1:1 with retries.
    demand = xv["transient_errors"]
    gate("transients paired with retries", xv["fetch_retries"] == demand,
         f"{xv['fetch_retries']} retries for {demand} demand transients")

    gate("storm converged: no quarantine", xv["quarantined_shards"] == 0,
         f"{xv['quarantined_shards']} quarantine entries")
    gate("storm converged: no degraded serves", xv["degraded_serves"] == 0,
         f"{xv['degraded_serves']} degraded serves")
    gate("every chaos request completed", xv["requests"] == cv["requests"],
         f"chaos {xv['requests']} vs clean {cv['requests']}")
    gate("nothing shed without admission knobs", xv["shed"] == 0,
         f"{xv['shed']} shed")

    p99_cap = env_f("RESMOE_FAULTS_P99_MS", max(250.0, 4.0 * cv["p99_ms"]))
    gate(f"chaos p99 <= {p99_cap:.0f} ms", xv["p99_ms"] <= p99_cap,
         f"{xv['p99_ms']:.1f} ms (clean {cv['p99_ms']:.1f} ms)")

    gate("instrument schema identical across runs",
         snapshot_schema(clean) == snapshot_schema(chaos),
         f"{sum(len(v) for v in snapshot_schema(clean).values())} instruments")

    report = {
        "bench": "fault_gates",
        "kernel": chaos.get("kernel"),
        "clean": cv,
        "chaos": xv,
        "gates": {"p99_cap_ms": p99_cap},
    }
    gates.write_report("faults", report)
    gates.finish()


if __name__ == "__main__":
    main()
