#!/usr/bin/env python3
"""Seeded simulation harness for PR 4 (cross-request continuous batching).

The container has no Rust toolchain, so this script model-checks the two
load-bearing claims of the PR against faithful Python ports of the Rust
state machines:

1. **Batcher replay** (`coordinator/batcher.rs`): the virtual-clock window
   state machine — the four scripted trace shapes (full-batch flush,
   linger-expiry flush, single straggler, quiesce-on-shutdown) plus
   randomized traces asserting windows never drop or reorder requests.

2. **Decision-order commutativity** (`coordinator/cache.rs`): with
   per-block-partitioned cache state, the serve-decision sequence is
   identical whether a window of requests is processed request-major
   (serial serving) or layer-major with request-major replay per layer
   (batched serving) — across monolithic and store modes, roomy/tight/
   thrash budgets, heat decay boundaries, and eviction storms. The same
   harness also runs the OLD design (one global budget/LRU/decay pool) and
   counts divergences, demonstrating that the partition is what makes
   batched == serial bit-for-bit possible.

   Soundness note: the sim fixes each request's routed slots up front.
   That is exactly the inductive step the Rust proof needs — requests are
   numerically independent and every kernel is row-independent, so IF both
   orders made identical decisions up to block b, hidden states (hence
   routing) at b are identical; the sim then shows decisions at b match.

3. **Window composition**: any partition of a request stream into
   consecutive windows leaves the partitioned state machine on the serial
   trajectory (the `prop_consecutive_windows_compose_like_serial_streams`
   property).

Run: python3 scripts/sim_batching.py   (exit 0 = all checks pass)
"""

import random
import sys

# Mirrors cache.rs constants.
HOT_ACCESSES = 3
HEAT_DECAY_PERIOD = 8  # 256 in Rust; small here to hammer decay boundaries
RESTORE_AMORTIZE_TOKENS = 64  # 512 in Rust; small to hit the rule


# ---------------------------------------------------------------- batcher

class Batcher:
    """Port of coordinator/batcher.rs::Batcher."""

    def __init__(self, max_batch, linger_us):
        self.max_batch = max(1, max_batch)
        self.linger_us = linger_us
        self.pending = []  # (item, arrival_us)
        self.closed = False

    def push(self, item, now_us):
        assert not self.closed
        self.pending.append((item, now_us))

    def deadline_us(self):
        return self.pending[0][1] + self.linger_us if self.pending else None

    def close(self):
        self.closed = True

    def poll(self, now_us):
        if not self.pending:
            return None
        if len(self.pending) >= self.max_batch:
            reason = "full"
        elif self.closed:
            reason = "closed"
        elif now_us >= self.deadline_us():
            reason = "linger"
        else:
            return None
        take = min(len(self.pending), self.max_batch)
        oldest = self.pending[0][1]
        items = [it for it, _ in self.pending[:take]]
        del self.pending[:take]
        return items, reason, max(0, now_us - oldest)


def check_batcher_replay():
    # Trace 1: full-batch flush (+ over-full remainder keeps its stamp).
    b = Batcher(4, 1000)
    for i, t in [(0, 10), (1, 20), (2, 30)]:
        b.push(i, t)
        assert b.poll(t) is None
    b.push(3, 40)
    items, reason, waited = b.poll(40)
    assert items == [0, 1, 2, 3] and reason == "full" and waited == 30
    for i in range(6):
        b.push(10 + i, 100 + i)
    items, reason, _ = b.poll(106)
    assert items == [10, 11, 12, 13] and reason == "full"
    assert b.deadline_us() == 104 + 1000
    items, reason, _ = b.poll(1104)
    assert items == [14, 15] and reason == "linger"

    # Trace 2: linger-expiry flush.
    b = Batcher(8, 500)
    b.push(1, 0); b.push(2, 200); b.push(3, 499)
    assert b.poll(499) is None
    items, reason, waited = b.poll(500)
    assert items == [1, 2, 3] and reason == "linger" and waited == 500
    assert b.poll(10_000) is None

    # Trace 3: single straggler ships alone at its deadline.
    b = Batcher(8, 300)
    b.push(42, 1000)
    assert b.poll(1299) is None
    items, reason, waited = b.poll(1300)
    assert items == [42] and reason == "linger" and waited == 300

    # Trace 4: quiesce-on-shutdown drains everything, in order.
    b = Batcher(4, 10**9)
    for i in range(6):
        b.push(i, i)
    b.close()
    items, reason, _ = b.poll(10)
    assert items == [0, 1, 2, 3] and reason == "full"
    items, reason, _ = b.poll(10)
    assert items == [4, 5] and reason == "closed"
    assert b.poll(10) is None

    # Randomized traces: windows concatenate to the admission order.
    rng = random.Random(0xBA7C4)
    for _ in range(500):
        b = Batcher(rng.randint(1, 6), rng.randint(0, 400))
        seen, nxt, now = [], 0, 0
        for _ in range(rng.randint(1, 120)):
            now += rng.randint(1, 50)
            if rng.random() < 0.7:
                b.push(nxt, now)
                nxt += 1
            got = b.poll(now)
            if got:
                seen.extend(got[0])
        b.close()
        while True:
            got = b.poll(now)
            if not got:
                break
            seen.extend(got[0])
        assert seen == list(range(nxt)), "dropped or reordered requests"
    print(f"[ok] batcher replay: 4 scripted traces + 500 randomized traces")


# ----------------------------------------------------- cache state machine

class BlockState:
    def __init__(self, budget):
        self.entries = {}   # slot -> last_used
        self.shards = {}    # eidx -> [last_used, bytes, has_split]
        self.center_built = False
        self.heat = {}
        self.serve_accesses = 0
        self.budget = budget
        self.used = 0
        self.shard_used = 0
        self.clock = 0


class Cache:
    """Port of the cache decision state machine (single-threaded serves).

    `partitioned=False` reproduces the OLD design: one global pool for
    budget, LRU clock, and heat decay (entries keyed (block, slot)).
    """

    def __init__(self, blocks, budget, expert_bytes, shard_bytes, split_bytes,
                 store_mode, partitioned=True):
        self.partitioned = partitioned
        self.store_mode = store_mode
        self.expert_bytes = expert_bytes  # per block dict
        self.shard_bytes = shard_bytes
        self.split_bytes = split_bytes
        if partitioned:
            share = budget // max(1, len(blocks))
            self.bs = {b: BlockState(share) for b in blocks}
        else:
            g = BlockState(budget)
            self.bs = {b: g for b in blocks}
            self.g = g
        self.metrics = dict(hits=0, misses=0, evictions=0, restore_serves=0,
                            fused_serves=0, restores_executed=0,
                            shard_fetches=0, shard_evictions=0)
        # Global-mode keys are (block, slot); partitioned keys are slot.
        self.key = (lambda b, s: s) if partitioned else (lambda b, s: (b, s))

    def _evict_dense_until_fits(self, bs, bytes_needed):
        while bs.used + bytes_needed > bs.budget and bs.entries:
            victim = min(bs.entries, key=lambda k: bs.entries[k])
            del bs.entries[victim]
            bs.used -= self._entry_bytes(victim)
            self.metrics["evictions"] += 1

    def _entry_bytes(self, key):
        b = key[0] if not self.partitioned else None
        # Partitioned mode: uniform per-block size looked up at serve time;
        # we stash it on the instance per serve (single block geometry).
        if self.partitioned:
            return self._cur_expert_bytes
        return self.expert_bytes[b]

    def _trim_shards(self, bs):
        while bs.used + bs.shard_used > bs.budget and bs.shards:
            victim = min(bs.shards, key=lambda k: bs.shards[k][0])
            bs.shard_used -= bs.shards[victim][1]
            del bs.shards[victim]
            self.metrics["shard_evictions"] += 1

    def _make_room_for_shard(self, bs, bytes_needed):
        while bs.used + bs.shard_used + bytes_needed > bs.budget and bs.shards:
            victim = min(bs.shards, key=lambda k: bs.shards[k][0])
            bs.shard_used -= bs.shards[victim][1]
            del bs.shards[victim]
            self.metrics["shard_evictions"] += 1

    def _shard_fetch(self, bs, block, eidx):
        if eidx in bs.shards:
            bs.shards[eidx][0] = bs.clock
            return
        self.metrics["shard_fetches"] += 1
        sb = self.shard_bytes[block]
        self._make_room_for_shard(bs, sb)
        bs.shards[eidx] = [bs.clock, sb, False]
        bs.shard_used += sb

    def serve(self, block, slot, tokens):
        bs = self.bs[block]
        self._cur_expert_bytes = self.expert_bytes[block]
        key = self.key(block, slot)
        bs.clock += 1
        # bump_heat
        bs.serve_accesses += 1
        bs.heat[key] = min(bs.heat.get(key, 0) + 1, 2**32 - 1)
        if bs.serve_accesses % HEAT_DECAY_PERIOD == 0:
            bs.heat = {k: v // 2 for k, v in bs.heat.items() if v // 2 > 0}
        if key in bs.entries:
            bs.entries[key] = bs.clock
            self.metrics["hits"] += 1
            return "H"
        self.metrics["misses"] += 1
        eb = self.expert_bytes[block]
        # should_restore
        if tokens >= RESTORE_AMORTIZE_TOKENS:
            restore = True
        elif bs.used + eb <= bs.budget:
            restore = True
        elif eb > bs.budget:
            restore = False
        else:
            restore = bs.heat.get(key, 0) >= HOT_ACCESSES
        if not restore:
            self.metrics["fused_serves"] += 1
            if self.store_mode:
                # fused_center (built once) + fused_shard: shard fetch +
                # split pieces charged to the pool.
                bs.center_built = True
                eidx = slot
                if eidx in bs.shards and bs.shards[eidx][2]:
                    bs.shards[eidx][0] = bs.clock
                else:
                    self._shard_fetch(bs, block, eidx)
                    sh = bs.shards.get(eidx)
                    if sh is not None and not sh[2]:
                        sh[2] = True
                        sh[1] += self.split_bytes[block]
                        bs.shard_used += self.split_bytes[block]
                        self._trim_shards(bs)
                return "F"
            return "F"
        self.metrics["restore_serves"] += 1
        if self.store_mode:
            self._shard_fetch(bs, block, slot)
        self.metrics["restores_executed"] += 1
        self._evict_dense_until_fits(bs, eb)
        bs.used += eb
        bs.entries[key] = bs.clock
        self._trim_shards(bs)
        return "R"


def run_order(cache, workload, order):
    """workload: list of requests; each request: {block: [(slot, tokens)...]}.

    order='serial'  → request-major (all of r's blocks, ascending).
    order='batched' → layer-major; within each block, requests in admission
                      order, slots ascending (the try_serve_batch replay).
    """
    trace = []
    blocks = sorted({b for r in workload for b in r})
    if order == "serial":
        for ri, r in enumerate(workload):
            for b in sorted(r):
                for slot, tokens in r[b]:
                    trace.append((ri, b, slot, cache.serve(b, slot, tokens)))
    else:
        for b in blocks:
            for ri, r in enumerate(workload):
                for slot, tokens in r.get(b, []):
                    trace.append((ri, b, slot, cache.serve(b, slot, tokens)))
        # Canonicalize to serial order for comparison: per-(request, block)
        # subsequences are identical either way; only the global interleave
        # differs.
        trace.sort(key=lambda t: (t[0], t[1]))
    return trace


def gen_workload(rng, n_requests=None):
    n_blocks = rng.randint(1, 3)
    blocks = sorted(rng.sample(range(1, 8), n_blocks))
    n_req = n_requests or rng.randint(1, 8)
    workload = []
    for _ in range(n_req):
        r = {}
        for b in blocks:
            slots = sorted(rng.sample(range(4), rng.randint(1, 3)))
            r[b] = [(s, rng.randint(1, 12) if rng.random() < 0.9
                     else RESTORE_AMORTIZE_TOKENS) for s in slots]
        workload.append(r)
    return blocks, workload


def make_caches(rng, blocks, partitioned, store_mode):
    eb = {b: rng.choice([80, 100, 120]) for b in blocks}
    sb = {b: max(8, eb[b] // rng.choice([4, 8])) for b in blocks}
    sp = {b: sb[b] // 2 for b in blocks}
    budget = rng.choice([
        10**9,                      # roomy
        0,                          # thrash
        max(eb.values()) * len(blocks),      # ~one expert per block share
        max(eb.values()) * 2 * len(blocks),  # two per share
        max(eb.values()) - 1,       # below one expert even undivided
        sum(eb.values()),           # awkward split
    ])
    mk = lambda: Cache(blocks, budget, eb, sb, sp, store_mode, partitioned)
    return mk, budget


def check_decision_commutativity():
    rng = random.Random(0xC0FFEE)
    cases = 3000
    for case in range(cases):
        blocks, workload = gen_workload(rng)
        store_mode = rng.random() < 0.5
        mk, budget = make_caches(rng, blocks, True, store_mode)
        serial, batched = mk(), mk()
        ts = run_order(serial, workload, "serial")
        tb = run_order(batched, workload, "batched")
        assert ts == tb, (
            f"case {case}: partitioned decisions diverged\n"
            f"budget={budget} store={store_mode} workload={workload}\n"
            f"serial ={ts}\nbatched={tb}")
        assert serial.metrics == batched.metrics, (
            f"case {case}: metrics diverged: {serial.metrics} vs {batched.metrics}")
    print(f"[ok] partitioned cache: {cases} randomized workloads — serial and "
          f"batched orders produce identical decisions and metrics")

    # The negative control: the OLD global pool diverges under the same
    # reordering — this is why the partition is load-bearing.
    rng = random.Random(0xDEAD)
    diverged = 0
    trials = 3000
    for _ in range(trials):
        blocks, workload = gen_workload(rng)
        if len(blocks) < 2:
            continue
        store_mode = rng.random() < 0.5
        mk, _ = make_caches(rng, blocks, False, store_mode)
        serial, batched = mk(), mk()
        ts = run_order(serial, workload, "serial")
        tb = run_order(batched, workload, "batched")
        if ts != tb or serial.metrics != batched.metrics:
            diverged += 1
    assert diverged > 0, "expected the global-pool design to diverge somewhere"
    print(f"[ok] global-pool control: {diverged}/{trials} workloads diverge "
          f"under batched reordering (partitioning is required for parity)")


def check_window_composition():
    rng = random.Random(0xBEEF)
    cases = 1000
    for case in range(cases):
        blocks, workload = gen_workload(rng, n_requests=rng.randint(2, 12))
        store_mode = rng.random() < 0.5
        mk, budget = make_caches(rng, blocks, True, store_mode)
        serial, windowed = mk(), mk()
        ts = run_order(serial, workload, "serial")
        # Random partition into consecutive windows, each run layer-major.
        tw = []
        i = 0
        while i < len(workload):
            j = min(len(workload), i + rng.randint(1, 5))
            sub = run_order(windowed, workload[i:j], "batched")
            tw.extend((ri + i, b, s, d) for ri, b, s, d in sub)
            i = j
        assert ts == tw and serial.metrics == windowed.metrics, (
            f"case {case}: window composition diverged (budget={budget})")
    print(f"[ok] window composition: {cases} randomized window partitions "
          f"stay on the serial trajectory")


if __name__ == "__main__":
    check_batcher_replay()
    check_decision_commutativity()
    check_window_composition()
    print("sim_batching: ALL CHECKS PASSED")
    sys.exit(0)
