"""Shared gate plumbing for the CI checkers (`check_obs.py`,
`check_faults.py`, `check_scenarios.py`).

Every checker consumes one or more `--metrics-out` JSON documents, runs a
list of named PASS/FAIL gates against them, writes a `reports/BENCH_*.json`
outcome document, and exits non-zero when any gate failed. This module owns
that plumbing — the checkers own only their gate logic.
"""

import json
import os


def env_f(name, default):
    """Float-valued env knob with a default (the gate-threshold pattern)."""
    return float(os.environ.get(name, default))


def load_json(path):
    with open(path) as f:
        return json.load(f)


def counters(doc):
    """The counter map of a `--metrics-out` document's registry snapshot."""
    return doc["snapshot"]["counters"]


def snapshot_schema(doc, keys=("counters", "gauges", "histograms")):
    """Sorted instrument names per snapshot section — two runs of the same
    binary must export identical schemas (measuring must not depend on the
    workload or on toggled subsystems)."""
    return {k: sorted(doc["snapshot"][k]) for k in keys}


class GateSet:
    """Accumulates named PASS/FAIL gates, prints each verdict as it lands."""

    def __init__(self, tool):
        self.tool = tool
        self.failures = []

    def gate(self, name, ok, detail):
        print(f"  {'PASS' if ok else 'FAIL'}  {name}: {detail}")
        if not ok:
            self.failures.append(f"{name}: {detail}")
        return ok

    @property
    def passed(self):
        return not self.failures

    def write_report(self, name, report):
        """Write the outcome document to `reports/BENCH_<name>.json`,
        stamping the shared failures/pass fields."""
        report = dict(report)
        report["failures"] = self.failures
        report["pass"] = self.passed
        os.makedirs("reports", exist_ok=True)
        path = os.path.join("reports", f"BENCH_{name}.json")
        with open(path, "w") as f:
            json.dump(report, f, indent=2)
            f.write("\n")
        print(f"  report -> {path}")
        return path

    def finish(self):
        """Exit non-zero when any gate failed (call last)."""
        if self.failures:
            raise SystemExit(f"{self.tool}: {len(self.failures)} gate(s) failed")
        print(f"{self.tool} OK")
