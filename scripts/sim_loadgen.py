#!/usr/bin/env python3
"""Python replica of the loadgen virtual-clock schedule + replay
(`rust/src/loadgen/{scenario,schedule}.rs`), ported line-for-line.

Everything on the schedule path is integer-only — xoshiro256** drawn
through integer quantile tables, saturating u64 arithmetic, nearest-rank
percentiles — so this replica reproduces the Rust schedules and replays
**bit-for-bit**: same events, same window compositions, same sheds, same
FNV-1a fingerprints. A toolchain-less session can therefore validate the
whole virtual-time story (and CI cross-checks the two implementations'
schedule fingerprints when both are available).

Checks (mirroring rust/tests + rust/src/loadgen unit tests):
  1. fixed seed => bit-identical schedule fingerprint across two runs;
     different seeds => different fingerprints (every scenario).
  2. replay conservation: executed + admission sheds + deadline sheds
     == arrivals; no request duplicated or lost (every scenario).
  3. sheds only in slow_reader, which must shed but not shed everything.
  4. zipf schedules put a super-proportional request share on the
     top-decile profiles (>= 2.0x for s=0.9, >= 2.5x for s=1.2); the
     bursty scenario forms both Full and Linger windows.
  5. the virtual service pipe is serial per tenant and latencies are
     exactly completion - arrival.
  6. the closed-loop client pool (gen_storm) issues monotone arrivals,
     never exceeds its in-flight bound, stays decode-dominated; open-loop
     scenarios report the schedule's arrivals verbatim.

Writes `reports/BENCH_scenarios.json` (source "python-sim"; the
engine-only fields — response/counter fingerprints, cache decisions,
expert-slot skew — are null) unless --no-report is given.

Usage: sim_loadgen.py [--seed N] [--no-report]
"""

import json
import os
import sys

MASK = (1 << 64) - 1

# ------------------------------------------------------------------- RNG
# xoshiro256** seeded via SplitMix64 (rust/src/util/rng.rs).


class Rng:
    def __init__(self, seed):
        s = []
        sm = seed & MASK
        for _ in range(4):
            sm = (sm + 0x9E3779B97F4A7C15) & MASK
            z = sm
            z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
            z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
            s.append(z ^ (z >> 31))
        self.s = s

    def next_u64(self):
        s = self.s
        x = (s[1] * 5) & MASK
        x = ((x << 7) | (x >> 57)) & MASK
        result = (x * 9) & MASK
        t = (s[1] << 17) & MASK
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = ((s[3] << 45) | (s[3] >> 19)) & MASK
        return result

    def below(self, n):
        """Lemire's method: high 64 bits of a 128-bit product."""
        return (self.next_u64() * n) >> 64


# ---------------------------------------------------------- fingerprints

FNV_OFFSET = 0xCBF29CE484222325
FNV_PRIME = 0x100000001B3


def fnv1a(h, data):
    for b in data:
        h = ((h ^ b) * FNV_PRIME) & MASK
    return h


def fnv1a_u64(h, v):
    return fnv1a(h, (v & MASK).to_bytes(8, "little"))


# ------------------------------------------------------------- scenarios
# Mirrors rust/src/loadgen/scenario.rs verbatim (integer tables included).

N_PROFILES = 32
GEN_NEW_TOKENS = 4
MIN_LEN = 4
LEN_RANGE = 12

EXP_Q1024 = [
    8, 24, 41, 58, 75, 92, 110, 128, 146, 165, 184, 203, 223, 243, 263, 284,
    305, 327, 349, 372, 395, 419, 444, 469, 494, 520, 547, 575, 603, 633,
    663, 694, 726, 759, 793, 828, 865, 903, 942, 983, 1026, 1070, 1117,
    1166, 1217, 1271, 1328, 1388, 1452, 1520, 1594, 1672, 1758, 1851, 1953,
    2067, 2195, 2342, 2513, 2719, 2976, 3320, 3844, 4968,
]

ZIPF09 = [
    1000000, 535887, 372041, 287175, 234924, 199372, 173545, 153893, 138415,
    125893, 115544, 106841, 99415, 93000, 87401, 82469, 78090, 74175, 70652,
    67464, 64566, 61918, 59490, 57255, 55189, 53275, 51496, 49838, 48288,
    46837, 45475, 44194,
]

ZIPF12 = [
    1000000, 435275, 267581, 189465, 144956, 116471, 96802, 82469, 71599,
    63096, 56277, 50697, 46054, 42135, 38787, 35897, 33378, 31165, 29208,
    27464, 25902, 24496, 23223, 22067, 21012, 20046, 19159, 18340, 17584,
    16883, 16232, 15625,
]


def base_scenario(name):
    return {
        "name": name,
        "requests": 96,
        "arrivals": {"kind": "poisson", "mean_gap_us": 400},
        "routing": {"kind": "uniform"},
        "mix": (1, 0, 0),  # score, generate, classify
        "max_queue": 0,
        "deadline_us": 0,
        "max_batch": 4,
        "linger_us": 800,
        "base_us": 300,
        "per_token_us": 40,
        "drain_gap_us": 0,
        "tenants": 1,
        "closed_loop_clients": 0,
    }


def canned_scenarios():
    zipf09 = dict(base_scenario("zipf09"),
                  routing={"kind": "zipf", "weights": ZIPF09})
    zipf12 = dict(base_scenario("zipf12"),
                  routing={"kind": "zipf", "weights": ZIPF12})
    bursty = dict(base_scenario("bursty"),
                  arrivals={"kind": "onoff", "burst_gap_us": 80,
                            "idle_gap_us": 5000, "burst_len": 8,
                            "ramp_permille": [250, 500, 1000, 2000, 1000, 500],
                            "ramp_period": 16},
                  max_batch=8, linger_us=1500)
    mixed = dict(base_scenario("mixed"),
                 arrivals={"kind": "poisson", "mean_gap_us": 500},
                 mix=(2, 1, 1))
    slow_reader = dict(base_scenario("slow_reader"),
                       arrivals={"kind": "poisson", "mean_gap_us": 150},
                       max_queue=64, deadline_us=20_000,
                       max_batch=4, linger_us=500, drain_gap_us=4000)
    multi_tenant = dict(base_scenario("multi_tenant"),
                        arrivals={"kind": "poisson", "mean_gap_us": 300},
                        routing={"kind": "zipf", "weights": ZIPF12},
                        tenants=2)
    gen_storm = dict(base_scenario("gen_storm"),
                     arrivals={"kind": "poisson", "mean_gap_us": 250},
                     routing={"kind": "zipf", "weights": ZIPF12},
                     mix=(1, 8, 1),
                     max_batch=8, linger_us=800,
                     closed_loop_clients=8)
    return [zipf09, zipf12, bursty, mixed, slow_reader, multi_tenant,
            gen_storm]


# --------------------------------------------------------------- schedule


def scenario_rng(seed, name):
    return Rng(seed ^ fnv1a(FNV_OFFSET, name.encode()))


def draw_gap(rng, arrivals, i):
    q = EXP_Q1024[rng.below(len(EXP_Q1024))]
    if arrivals["kind"] == "poisson":
        return arrivals["mean_gap_us"] * q // 1024
    cycle = arrivals["burst_len"] + 1
    base = (arrivals["burst_gap_us"] if i % cycle < arrivals["burst_len"]
            else arrivals["idle_gap_us"])
    ramp = arrivals["ramp_permille"]
    step = (i // arrivals["ramp_period"]) % len(ramp)
    intensity = max(ramp[step], 1)
    return base * q // 1024 * 1000 // intensity


def draw_profile(rng, routing):
    if routing["kind"] == "uniform":
        return rng.below(N_PROFILES)
    weights = routing["weights"]
    r = rng.below(sum(weights))
    for i, w in enumerate(weights):
        if r < w:
            return i
        r -= w
    return len(weights) - 1


def generate(sc, seed):
    """Events as (t_us, profile, kind, len, tenant); draw order per event
    is gap, profile, kind, len, [tenant] — identical to schedule.rs."""
    rng = scenario_rng(seed, sc["name"])
    score, gen, classify = sc["mix"]
    kind_total = score + gen + classify
    assert kind_total > 0
    t = 0
    events = []
    for i in range(sc["requests"]):
        t = min(t + draw_gap(rng, sc["arrivals"], i), MASK)
        profile = draw_profile(rng, sc["routing"])
        r = rng.below(kind_total)
        kind = 0 if r < score else (1 if r < score + gen else 2)
        length = MIN_LEN + rng.below(LEN_RANGE)
        tenant = rng.below(sc["tenants"]) if sc["tenants"] > 1 else 0
        events.append((t, profile, kind, length, tenant))
    return events


def event_tokens(ev):
    return ev[3] + (GEN_NEW_TOKENS if ev[2] == 1 else 0)


def schedule_fingerprint(events):
    h = FNV_OFFSET
    for ev in events:
        for field in ev:
            h = fnv1a_u64(h, field)
    return h


# ----------------------------------------------------------------- replay
# Port of coordinator::Batcher (rust/src/coordinator/batcher.rs) and the
# replay loop of rust/src/loadgen/schedule.rs.

FULL, LINGER, CLOSED = "full", "linger", "closed"


class Batcher:
    def __init__(self, max_batch, linger_us):
        self.max_batch = max_batch
        self.linger_us = linger_us
        self.pending = []  # (item, arrived_us) in arrival order
        self.closed = False

    def push(self, item, now_us):
        self.pending.append((item, now_us))

    def pending_len(self):
        return len(self.pending)

    def deadline_us(self):
        if not self.pending:
            return None
        return min(self.pending[0][1] + self.linger_us, MASK)

    def close(self):
        self.closed = True

    def poll(self, now_us):
        if not self.pending:
            return None
        if len(self.pending) >= self.max_batch:
            reason = FULL
        elif self.closed:
            reason = CLOSED
        elif now_us >= self.deadline_us():
            reason = LINGER
        else:
            return None
        take = min(len(self.pending), self.max_batch)
        oldest = self.pending[0][1]
        items = [item for item, _ in self.pending[:take]]
        del self.pending[:take]
        return items, reason, max(now_us - oldest, 0)


class TenantState:
    def __init__(self, sc):
        self.batcher = Batcher(sc["max_batch"], sc["linger_us"])
        self.busy_until_us = 0
        self.drain_cursor_us = 0
        self.drains_us = []  # nondecreasing

    def undrained_at(self, t):
        # partition_point(|d| d <= t) on a nondecreasing list.
        lo, hi = 0, len(self.drains_us)
        while lo < hi:
            mid = (lo + hi) // 2
            if self.drains_us[mid] <= t:
                lo = mid + 1
            else:
                hi = mid
        return len(self.drains_us) - lo


class Replay:
    def __init__(self, n):
        self.windows = []  # dicts: tenant/formed/reason/waited/live/shed/...
        self.admit_shed = []
        self.deadline_shed = []
        self.latency_us = [None] * n
        self.ttft_us = [None] * n
        # Effective arrival per schedule index: t_us verbatim (open loop)
        # or when the issuing client became ready (closed loop).
        self.arrival_us = [0] * n


def execute_window(sc, events, st, tenant, idxs, reason, formed_us,
                   waited_us, out):
    exec_start = max(formed_us, st.busy_until_us)
    live, shed = [], []
    for idx in idxs:
        waited = max(exec_start - out.arrival_us[idx], 0)
        if sc["deadline_us"] > 0 and waited > sc["deadline_us"]:
            shed.append(idx)
        else:
            live.append(idx)
    tokens = sum(event_tokens(events[i]) for i in live)
    dur = 0 if not live else sc["base_us"] + sc["per_token_us"] * tokens
    completion = exec_start + dur
    st.busy_until_us = completion
    for idx in live:
        out.latency_us[idx] = completion - out.arrival_us[idx]
        if events[idx][2] == 1:
            out.ttft_us[idx] = (
                max(exec_start + sc["base_us"] - out.arrival_us[idx], 0))
        drain = max(completion, st.drain_cursor_us)
        st.drain_cursor_us = drain + sc["drain_gap_us"]
        st.drains_us.append(drain)
    out.deadline_shed.extend(shed)
    out.windows.append({
        "tenant": tenant, "formed_us": formed_us, "reason": reason,
        "waited_us": waited_us, "live": live, "shed": shed,
        "exec_start_us": exec_start, "completion_us": completion,
        "dur_us": dur,
    })


def flush_due(sc, events, st, tenant, now_us, out):
    while True:
        dl = st.batcher.deadline_us()
        if dl is None or dl > now_us:
            break
        w = st.batcher.poll(dl)
        if w is None:
            break
        items, reason, waited = w
        execute_window(sc, events, st, tenant, items, reason, dl, waited, out)


def replay(sc, events):
    out = Replay(len(events))
    out.arrival_us = [ev[0] for ev in events]
    if sc["closed_loop_clients"] > 0:
        replay_closed(sc, events, out)
        return out
    tenants = [TenantState(sc) for _ in range(max(sc["tenants"], 1))]
    for i, ev in enumerate(events):
        for t, st in enumerate(tenants):
            flush_due(sc, events, st, t, ev[0], out)
        st = tenants[ev[4]]
        depth = st.batcher.pending_len() + st.undrained_at(ev[0])
        if sc["max_queue"] > 0 and depth >= sc["max_queue"]:
            out.admit_shed.append(i)
            continue
        st.batcher.push(i, ev[0])
        w = st.batcher.poll(ev[0])
        if w is not None:
            items, reason, waited = w
            execute_window(sc, events, st, ev[4], items, reason, ev[0],
                           waited, out)
    t_end = events[-1][0] if events else 0
    for t, st in enumerate(tenants):
        flush_due(sc, events, st, t, MASK, out)
        st.batcher.close()
        while True:
            w = st.batcher.poll(t_end)
            if w is None:
                break
            items, reason, waited = w
            execute_window(sc, events, st, t, items, reason, t_end, waited,
                           out)
    return out


def unblock_clients(windows, seen, owner, ready):
    """Mark clients whose requests finished in windows[seen:] ready: live
    members at the window completion, shed members at pickup."""
    for w in windows[seen:]:
        for i in w["live"]:
            if owner[i] != -1:
                ready[owner[i]] = w["completion_us"]
        for i in w["shed"]:
            if owner[i] != -1:
                ready[owner[i]] = w["exec_start_us"]
    return len(windows)


def replay_closed(sc, events, out):
    """Closed-loop replay: a fixed pool issues events in schedule order,
    at most one outstanding request per client; event i's think time is
    the schedule's inter-arrival gap. Ported from schedule.rs verbatim."""
    clients = sc["closed_loop_clients"]
    tenants = [TenantState(sc) for _ in range(max(sc["tenants"], 1))]
    ready = [0] * clients  # next-issue instant; MASK while blocked
    owner = [-1] * len(events)  # schedule index -> issuing client
    seen = 0
    next_ev = 0
    now = 0
    while next_ev < len(events):
        c, r = min(enumerate(ready), key=lambda p: (p[1], p[0]))
        if r == MASK:
            # Every client is blocked: jump to the earliest linger
            # deadline, whose flush completes a window and unblocks it.
            deadlines = [st.batcher.deadline_us() for st in tenants]
            dl = min(d for d in deadlines if d is not None)
            now = max(now, dl)
            for tn, st in enumerate(tenants):
                flush_due(sc, events, st, tn, now, out)
            seen = unblock_clients(out.windows, seen, owner, ready)
            continue
        i = next_ev
        next_ev += 1
        think = events[0][0] if i == 0 else events[i][0] - events[i - 1][0]
        t = max(now, min(r + think, MASK))
        now = t
        out.arrival_us[i] = t
        for tn, st in enumerate(tenants):
            flush_due(sc, events, st, tn, t, out)
        tn = events[i][4]
        st = tenants[tn]
        depth = st.batcher.pending_len() + st.undrained_at(t)
        if sc["max_queue"] > 0 and depth >= sc["max_queue"]:
            out.admit_shed.append(i)
            ready[c] = t  # instant Overloaded answer; think again from t
        else:
            owner[i] = c
            ready[c] = MASK
            st.batcher.push(i, t)
            w = st.batcher.poll(t)
            if w is not None:
                items, reason, waited = w
                execute_window(sc, events, st, tn, items, reason, t,
                               waited, out)
        seen = unblock_clients(out.windows, seen, owner, ready)
    for tn, st in enumerate(tenants):
        flush_due(sc, events, st, tn, MASK, out)
        st.batcher.close()
        while True:
            w = st.batcher.poll(now)
            if w is None:
                break
            items, reason, waited = w
            execute_window(sc, events, st, tn, items, reason, now, waited,
                           out)


def percentile_us(sample, q):
    """Nearest-rank on the sorted sample: index (n-1)*q//100 (integer)."""
    if not sample:
        return None
    v = sorted(sample)
    return v[(len(v) - 1) * q // 100]


# ----------------------------------------------------------------- checks


def check(name, ok, detail=""):
    print(f"  {'PASS' if ok else 'FAIL'}  {name}" + (f": {detail}" if detail else ""))
    return ok


def scenario_report(sc, seed, events, rp):
    executed = sum(len(w["live"]) for w in rp.windows)
    lat = [l for l in rp.latency_us if l is not None]
    ttft = [l for l in rp.ttft_us if l is not None]
    live_tokens = sum(event_tokens(events[i])
                      for w in rp.windows for i in w["live"])
    makespan = (max((w["completion_us"] for w in rp.windows), default=0)
                - (rp.arrival_us[0] if rp.arrival_us else 0))
    reasons = [w["reason"] for w in rp.windows]
    nonempty = sum(1 for w in rp.windows if w["live"])

    def ms(us):
        return None if us is None else us / 1000.0

    return {
        "scenario": sc["name"],
        "seed": seed,
        "vworkers": None,
        "tenants": max(sc["tenants"], 1),
        "arrivals": len(events),
        "executed": executed,
        "shed_admission": len(rp.admit_shed),
        "shed_deadline": len(rp.deadline_shed),
        "errors": 0,
        "degraded": 0,
        "classify_disabled": None,
        "virtual": {
            "p50_ms": ms(percentile_us(lat, 50)),
            "p99_ms": ms(percentile_us(lat, 99)),
            "ttft_p50_ms": ms(percentile_us(ttft, 50)),
            "ttft_p99_ms": ms(percentile_us(ttft, 99)),
            "tok_s": live_tokens * 1e6 / makespan if makespan else 0.0,
            "makespan_ms": makespan / 1000.0,
            "windows": nonempty,
            "windows_full": reasons.count(FULL),
            "windows_linger": reasons.count(LINGER),
            "windows_closed": reasons.count(CLOSED),
            "mean_batch": executed / nonempty if nonempty else 0.0,
        },
        "pool": {"p50_ms": None, "p99_ms": None},
        "cache": None,
        "skew": None,
        "fingerprints": {
            "schedule": f"{schedule_fingerprint(events):016x}",
            "responses": None,
            "counters": None,
        },
    }


def main():
    seed = 7
    write_report = True
    args = sys.argv[1:]
    while args:
        a = args.pop(0)
        if a == "--seed":
            seed = int(args.pop(0))
        elif a == "--no-report":
            write_report = False
        else:
            sys.exit(f"usage: {sys.argv[0]} [--seed N] [--no-report]")

    failures = 0
    docs = []
    for sc in canned_scenarios():
        name = sc["name"]
        events = generate(sc, seed)
        fp = schedule_fingerprint(events)
        fp2 = schedule_fingerprint(generate(sc, seed))
        other = schedule_fingerprint(generate(sc, seed + 1))
        failures += not check(f"{name}: schedule deterministic",
                              fp == fp2, f"{fp:016x}")
        failures += not check(f"{name}: schedule seed-sensitive", fp != other)

        rp = replay(sc, events)
        executed = sum(len(w["live"]) for w in rp.windows)
        sheds = len(rp.admit_shed) + len(rp.deadline_shed)
        failures += not check(
            f"{name}: conservation",
            executed + sheds == len(events),
            f"{executed} executed + {sheds} shed == {len(events)} arrivals")
        seen = set()
        dup = False
        for w in rp.windows:
            for idx in w["live"] + w["shed"]:
                dup = dup or idx in seen
                seen.add(idx)
        for idx in rp.admit_shed:
            dup = dup or idx in seen
            seen.add(idx)
        failures += not check(f"{name}: no request duplicated or lost",
                              not dup and len(seen) == len(events))
        if name == "slow_reader":
            failures += not check(f"{name}: sheds under backpressure",
                                  0 < sheds < len(events), f"{sheds} shed")
        else:
            failures += not check(f"{name}: no sheds intended",
                                  sheds == 0, f"{sheds} shed")
        # Serial virtual pipe per tenant.
        ok = True
        for t in range(max(sc["tenants"], 1)):
            last = 0
            for w in (w for w in rp.windows if w["tenant"] == t):
                ok = ok and w["exec_start_us"] >= max(w["formed_us"], last)
                last = w["completion_us"]
        failures += not check(f"{name}: virtual pipe serial per tenant", ok)
        docs.append(scenario_report(sc, seed, events, rp))

    # Schedule-level zipf skew (the cache-level half runs in
    # check_scenarios.py against the Rust engine run).
    for name, min_ratio in (("zipf09", 2.0), ("zipf12", 2.5)):
        sc = next(s for s in canned_scenarios() if s["name"] == name)
        events = generate(sc, seed)
        counts = [0] * N_PROFILES
        for ev in events:
            counts[ev[1]] += 1
        top = -(-N_PROFILES // 10)
        share = sum(sorted(counts, reverse=True)[:top])
        ratio = (share / len(events)) / (top / N_PROFILES)
        failures += not check(
            f"{name}: top-decile profile ratio >= {min_ratio}",
            ratio >= min_ratio, f"{ratio:.2f}x proportional")

    sc = next(s for s in canned_scenarios() if s["name"] == "bursty")
    rp = replay(sc, generate(sc, seed))
    reasons = {w["reason"] for w in rp.windows}
    failures += not check("bursty: forms Full and Linger windows",
                          FULL in reasons and LINGER in reasons,
                          ",".join(sorted(reasons)))

    # Closed-loop client model (gen_storm): arrivals monotone, in-flight
    # never exceeds the pool, and the mix is decode-dominated. Mirrors
    # closed_loop_bounds_in_flight_requests in schedule.rs.
    sc = next(s for s in canned_scenarios() if s["name"] == "gen_storm")
    events = generate(sc, seed)
    rp = replay(sc, events)
    failures += not check(
        "gen_storm: closed-loop arrivals monotone",
        all(a <= b for a, b in zip(rp.arrival_us, rp.arrival_us[1:])))
    done = [0] * len(events)
    for w in rp.windows:
        for i in w["live"]:
            done[i] = w["completion_us"]
        for i in w["shed"]:
            done[i] = w["exec_start_us"]
    for i in rp.admit_shed:
        done[i] = rp.arrival_us[i]
    pool = sc["closed_loop_clients"]
    worst = max(
        (sum(1 for j in range(len(events))
             if rp.arrival_us[j] <= a and done[j] > a)
         for a in rp.arrival_us),
        default=0)
    failures += not check(
        f"gen_storm: in-flight bounded by pool of {pool}",
        worst <= pool, f"peak {worst} in flight")
    gens = sum(1 for ev in events if ev[2] == 1)
    failures += not check(
        "gen_storm: decode-dominated mix",
        gens * 2 >= len(events), f"{gens}/{len(events)} generates")
    # Open loop leaves arrivals verbatim (closed loop generalizes them).
    sc = next(s for s in canned_scenarios() if s["name"] == "mixed")
    events = generate(sc, seed)
    rp = replay(sc, events)
    failures += not check(
        "mixed: open-loop arrivals pass through verbatim",
        all(a == ev[0] for a, ev in zip(rp.arrival_us, events)))

    if write_report:
        os.makedirs("reports", exist_ok=True)
        doc = {
            "bench": "scenarios",
            "source": "python-sim",
            "kernel": None,
            "seed": seed,
            "vworkers": None,
            "scenarios": docs,
        }
        with open("reports/BENCH_scenarios.json", "w") as f:
            json.dump(doc, f, indent=2)
            f.write("\n")
        print("  report -> reports/BENCH_scenarios.json (source python-sim)")

    if failures:
        sys.exit(f"sim_loadgen: {failures} check(s) failed")
    print("sim_loadgen OK")


if __name__ == "__main__":
    main()
