#!/usr/bin/env python3
"""Seeded simulation harness for PR 8 (fault-tolerant serving).

The container has no Rust toolchain, so this script model-checks the
load-bearing claims of the PR against faithful Python ports of the Rust
state machines:

1. **Fault-plan replay** (`util/fault.rs`): the `RESMOE_FAULTS` grammar
   parses, per-target attempt counters make decisions a pure function of
   `(seed, rule, site, block, slot, attempt)`, and the same plan replays
   bit-identically under any interleaving of targets.

2. **Retry convergence** (`coordinator/cache.rs::shard_expert`): transient
   faults that exhaust before the retry budget (`count <= 3`) leave serve
   outcomes — values, decisions, health — identical to the fault-free run;
   every injected transient pairs with exactly one backed-off retry.

3. **Quarantine lifecycle**: integrity faults are never retried; the third
   consecutive whole-fetch failure opens a quarantine spell (TTL 250 ms,
   doubling per re-entry, capped at 2^6); quarantined serves degrade to
   the barycenter *without touching the store*; TTL expiry admits exactly
   one half-open probe; a successful probe clears the health entry.

4. **Attribution parity** (`coordinator/server.rs`): per-request error
   pinning in the batched window path (layer-major, per-want serial
   replay) reproduces the serial path's attribution exactly — same
   failing requests, same messages — even across the quarantine
   threshold, because both orders fail the shared target in admission
   order.

5. **Admission control**: under random submit/drain schedules with a
   queue bound and per-request deadlines, every request gets exactly one
   response (executed, queue-shed, or deadline-shed), the shed counter
   matches, and the depth gauge returns to zero.

Run: python3 scripts/sim_faults.py   (exit 0 = all checks pass)
"""

import random
import sys

# Mirrors cache.rs constants (times are virtual microseconds).
FETCH_RETRY_LIMIT = 3
FETCH_BACKOFF_US = 50
QUARANTINE_THRESHOLD = 3
QUARANTINE_TTL_US = 250_000
QUARANTINE_MAX_SPELLS = 6


# ------------------------------------------------------------ fault plan

class Rule:
    """Port of util/fault.rs::Rule (one spec clause)."""

    KINDS = ("transient", "corrupt", "truncate", "latency")

    def __init__(self, kind, site, block=None, slot=None, count=None,
                 prob=1.0, latency_us=200):
        self.kind, self.site = kind, site
        self.block, self.slot = block, slot
        self.count, self.prob, self.latency_us = count, prob, latency_us

    @classmethod
    def parse(cls, src):
        kind, _, rest = src.partition("@")
        if not _:
            raise ValueError(f"rule {src!r}: want <kind>@<site>")
        if kind not in cls.KINDS:
            raise ValueError(f"rule {src!r}: unknown kind {kind!r}")
        # A leading '*' is the wildcard site, not the count marker.
        if rest.startswith("*"):
            cut = 1
        else:
            cut = len(rest)
            for m in "/*~+":
                if m in rest:
                    cut = min(cut, rest.index(m))
        rule = cls(kind, rest[:cut])
        if not rule.site:
            raise ValueError(f"rule {src!r}: empty site")
        tail = rest[cut:]
        while tail:
            marker, tail = tail[0], tail[1:]
            end = len(tail)
            for m in "/*~+":
                if m in tail:
                    end = min(end, tail.index(m))
            body, tail = tail[:end], tail[end:]
            if marker == "/":
                if not body.startswith("b"):
                    raise ValueError(f"rule {src!r}: target wants /b<block>[e<expert>]")
                b, _, e = body[1:].partition("e")
                rule.block = int(b)
                rule.slot = int(e) if e else None
            elif marker == "*":
                rule.count = int(body)
            elif marker == "~":
                rule.prob = float(body)
            elif marker == "+":
                rule.latency_us = int(body)
        return rule

    def matches(self, site, block, slot):
        return ((self.site == "*" or self.site == site)
                and (self.block is None or self.block == block)
                and (self.slot is None or self.slot == slot))


class FaultPlan:
    """Port of util/fault.rs::FaultPlan + the registry's check()."""

    def __init__(self, seed, rules):
        self.seed, self.rules = seed, rules
        self.attempts = {}  # (site, block, slot) -> count

    @classmethod
    def parse(cls, env):
        head, sep, spec = env.partition("spec:")
        if not sep:
            raise ValueError("RESMOE_FAULTS needs a 'spec:' section")
        seed = 0
        for part in head.split(","):
            part = part.strip()
            if not part:
                continue
            if part.startswith("seed:"):
                seed = int(part[5:])
            else:
                raise ValueError(f"unknown RESMOE_FAULTS key {part!r}")
        rules = [Rule.parse(r.strip()) for r in spec.split(";") if r.strip()]
        if not rules:
            raise ValueError("empty fault spec")
        return cls(seed, rules)

    def _draw(self, rule_idx, site, block, slot, attempt):
        # Deterministic hash -> uniform; mirrors the SHAPE of the Rust draw
        # (pure in target identity + attempt), not its exact bits.
        h = hash((self.seed, rule_idx, site, block, slot, attempt))
        return random.Random(h).random()

    def check(self, site, block, slot):
        key = (site, block, slot)
        attempt = self.attempts.get(key, 0)
        self.attempts[key] = attempt + 1
        for i, rule in enumerate(self.rules):
            if not rule.matches(site, block, slot):
                continue
            if rule.count is not None and attempt >= rule.count:
                continue
            if rule.prob < 1.0 and self._draw(i, site, block, slot, attempt) >= rule.prob:
                continue
            return rule.kind
        return None

    def reset(self):
        self.attempts = {}


def check_plan_replay():
    # Grammar round-trip.
    p = FaultPlan.parse("seed:42,spec:transient@store.read*2;"
                        "corrupt@store.read/b1e3;latency@*~0.5+300")
    assert p.seed == 42 and len(p.rules) == 3
    assert p.rules[0].count == 2 and p.rules[1].block == 1 and p.rules[1].slot == 3
    assert p.rules[2].site == "*" and p.rules[2].prob == 0.5
    for bad in ["no spec", "spec:", "spec:transient", "spec:boom@x",
                "spec:transient@store.read*x", "seed:z,spec:transient@*"]:
        try:
            FaultPlan.parse(bad)
        except ValueError:
            pass
        else:
            raise AssertionError(f"{bad!r} should not parse")

    # Per-target decisions are interleaving-independent: any shuffle of the
    # same multiset of (target, attempt#) probes yields the same per-target
    # decision sequences.
    rng = random.Random(0xFA01)
    for trial in range(200):
        spec = rng.choice([
            "seed:7,spec:transient@store.read*2",
            "seed:9,spec:transient@store.read~0.4",
            "seed:3,spec:corrupt@store.read/b1;transient@*~0.7*4",
        ])
        targets = [("store.read", rng.randrange(3), rng.randrange(4))
                   for _ in range(rng.randint(4, 10))]
        probes = [t for t in targets for _ in range(rng.randint(1, 5))]

        def run(order):
            plan = FaultPlan.parse(spec)
            seq = {}
            for t in order:
                seq.setdefault(t, []).append(plan.check(*t))
            return seq

        a = run(probes)
        shuffled = probes[:]
        rng.shuffle(shuffled)
        b = run(shuffled)
        assert a == b, f"trial {trial}: interleaving changed fault decisions"
    print("[ok] fault-plan replay: grammar + 200 interleaving shuffles are "
          "decision-identical per target")


# --------------------------------------------------- cache fault machine

INTEGRITY = ("checksum mismatch", "decompression failed", "index says",
             "bad shard payload")


def classify(msg):
    return "integrity" if any(m in msg for m in INTEGRITY) else "transient"


class Store:
    """Shard store whose read path consults a fault plan (format.rs)."""

    def __init__(self, plan, shards):
        self.plan = plan
        self.shards = shards  # (block, eidx) -> value
        self.reads = 0

    def load(self, block, eidx):
        self.reads += 1
        kind = self.plan.check("store.read", block, eidx) if self.plan else None
        if kind == "transient":
            raise IOError(f"block {block} expert {eidx}: injected transient read error")
        if kind == "truncate":
            raise IOError(f"block {block} expert {eidx}: short read (injected truncation)")
        if kind == "corrupt":
            raise IOError(f"block {block} expert {eidx}: checksum mismatch")
        return self.shards[(block, eidx)]


class Cache:
    """Port of shard_expert's retry/quarantine/degrade path, virtual time."""

    def __init__(self, store, centers):
        self.store = store
        self.centers = centers  # block -> center value (None = no center)
        self.health = {}  # (block, eidx) -> [consecutive_failures, until, spells]
        self.now_us = 0
        self.m = {"transient_errors": 0, "fetch_retries": 0,
                  "quarantined_shards": 0, "degraded_serves": 0}

    def _fetch(self, block, eidx):
        """Bounded retry inside the singleflight materialize."""
        attempt = 0
        while True:
            try:
                return self.store.load(block, eidx)
            except IOError as e:
                if classify(str(e)) == "transient":
                    self.m["transient_errors"] += 1
                    if attempt < FETCH_RETRY_LIMIT:
                        self.m["fetch_retries"] += 1
                        self.now_us += FETCH_BACKOFF_US * (1 << attempt)
                        attempt += 1
                        continue
                raise

    def serve(self, block, eidx):
        """Returns ('ok', value) | ('degraded', center) | ('error', msg)."""
        h = self.health.get((block, eidx))
        if h and h[1] is not None and self.now_us < h[1]:
            return self._fail(block, eidx,
                             f"block {block} expert {eidx}: quarantined after "
                             "repeated fetch failures", fetched=False)
        try:
            value = self._fetch(block, eidx)
        except IOError as e:
            return self._fail(block, eidx, str(e), fetched=True)
        self.health.pop((block, eidx), None)  # success clears the streak
        return ("ok", value)

    def _fail(self, block, eidx, msg, fetched):
        if fetched:
            h = self.health.setdefault((block, eidx), [0, None, 0])
            h[0] += 1
            if h[0] >= QUARANTINE_THRESHOLD:
                exp = min(h[2], QUARANTINE_MAX_SPELLS)
                h[1] = self.now_us + QUARANTINE_TTL_US * (1 << exp)
                h[2] += 1
                self.m["quarantined_shards"] += 1
        center = self.centers.get(block)
        if center is not None:
            self.m["degraded_serves"] += 1
            return ("degraded", center)
        return ("error", msg)


def make_world(plan, blocks=2, experts=4, centers=True):
    shards = {(b, e): f"w[{b}.{e}]" for b in range(blocks) for e in range(experts)}
    cmap = {b: (f"center[{b}]" if centers else None) for b in range(blocks)}
    store = Store(plan, shards)
    return store, Cache(store, cmap)


def check_retry_convergence():
    rng = random.Random(0xFA02)
    trials = 300
    for trial in range(trials):
        count = rng.randint(1, FETCH_RETRY_LIMIT)  # exhausts before budget
        plan = FaultPlan.parse(f"seed:{trial},spec:transient@store.read*{count}")
        workload = [(rng.randrange(2), rng.randrange(4))
                    for _ in range(rng.randint(5, 30))]

        _, clean = make_world(None)
        want = [clean.serve(b, e) for b, e in workload]
        _, faulted = make_world(plan)
        got = [faulted.serve(b, e) for b, e in workload]

        assert want == got, f"trial {trial}: converging storm changed an outcome"
        assert all(k == "ok" for k, _ in got)
        assert faulted.health == {}, "converged storm must leave health empty"
        distinct = len(set(workload))
        assert faulted.m["transient_errors"] == count * distinct
        assert faulted.m["fetch_retries"] == faulted.m["transient_errors"], (
            "every transient under the budget pairs with exactly one retry")
        assert faulted.m["quarantined_shards"] == 0
        assert faulted.m["degraded_serves"] == 0
    print(f"[ok] retry convergence: {trials} transient storms (count <= "
          f"{FETCH_RETRY_LIMIT}) are outcome-identical to fault-free runs")


def check_quarantine_lifecycle():
    plan = FaultPlan.parse("seed:5,spec:corrupt@store.read/b0e1")
    store, cache = make_world(plan)

    # Integrity failures: never retried, degraded immediately.
    for i in range(QUARANTINE_THRESHOLD):
        assert cache.serve(0, 1) == ("degraded", "center[0]"), f"serve {i}"
    assert cache.m["transient_errors"] == 0 and cache.m["fetch_retries"] == 0
    assert cache.m["quarantined_shards"] == 1, "third failure opens the spell"

    # Quarantined: degrade WITHOUT touching the store.
    reads = store.reads
    for _ in range(10):
        assert cache.serve(0, 1)[0] == "degraded"
    assert store.reads == reads, "quarantined serves must not read the store"

    # TTL expiry admits a probe; still corrupt -> re-quarantine, TTL doubled.
    cache.now_us = cache.health[(0, 1)][1]
    assert cache.serve(0, 1)[0] == "degraded"
    assert store.reads == reads + 1, "exactly one half-open probe"
    assert cache.m["quarantined_shards"] == 2
    ttl2 = cache.health[(0, 1)][1] - cache.now_us
    assert ttl2 == 2 * QUARANTINE_TTL_US, "re-entry doubles the TTL"

    # Heal the shard: the next probe succeeds and clears health.
    cache.now_us = cache.health[(0, 1)][1]
    plan.rules = []  # fault cleared
    assert cache.serve(0, 1) == ("ok", "w[0.1]")
    assert (0, 1) not in cache.health, "success clears the failure streak"

    # TTL growth caps at 2^QUARANTINE_MAX_SPELLS.
    plan2 = FaultPlan.parse("seed:6,spec:corrupt@store.read/b1e0")
    _, c2 = make_world(plan2)
    last_ttl = None
    for _ in range(QUARANTINE_MAX_SPELLS + 4):
        while (1, 0) not in c2.health or c2.health[(1, 0)][1] is None \
                or c2.now_us >= c2.health[(1, 0)][1]:
            c2.serve(1, 0)
        last_ttl = c2.health[(1, 0)][1] - c2.now_us
        c2.now_us = c2.health[(1, 0)][1]
    assert last_ttl == QUARANTINE_TTL_US * (1 << QUARANTINE_MAX_SPELLS), (
        f"TTL must cap at 2^{QUARANTINE_MAX_SPELLS}: {last_ttl}")

    # No center -> the same machine surfaces errors instead of degrading.
    plan3 = FaultPlan.parse("seed:7,spec:corrupt@store.read/b0e2")
    _, c3 = make_world(plan3, centers=False)
    kind, msg = c3.serve(0, 2)
    assert kind == "error" and "checksum mismatch" in msg
    for _ in range(4):
        c3.serve(0, 2)
    kind, msg = c3.serve(0, 2)
    assert kind == "error" and "quarantined" in msg, (
        "center-less quarantine surfaces the quarantine error")
    print("[ok] quarantine lifecycle: threshold, probe economy, TTL doubling "
          "with cap, heal-on-success, center-less error surfacing")


def check_attribution_parity():
    """Serial (request-major) vs batched (layer-major with per-want serial
    replay) must produce identical per-request outcomes — including which
    requests see 'checksum mismatch' vs 'quarantined' around the threshold."""
    rng = random.Random(0xFA03)
    trials = 400
    for trial in range(trials):
        n_blocks, n_experts = 2, 4
        bad = (rng.randrange(n_blocks), rng.randrange(n_experts))
        centers = rng.random() < 0.5
        plan_s = f"seed:{trial},spec:corrupt@store.read/b{bad[0]}e{bad[1]}"
        # Each request activates a sorted slot set per block (top-k routing).
        reqs = [{b: sorted(rng.sample(range(n_experts), rng.randint(1, 2)))
                 for b in range(n_blocks)} for _ in range(rng.randint(2, 8))]

        def first_fault(cache, req):
            """First-error-wins per request; degraded marks the answer."""
            outcome, msg = "ok", None
            for b in sorted(req):
                for e in req[b]:
                    kind, payload = cache.serve(b, e)
                    if kind == "error" and msg is None:
                        outcome, msg = "error", payload
                    elif kind == "degraded" and outcome == "ok":
                        outcome = "degraded"
            return (outcome, msg)

        _, serial = make_world(FaultPlan.parse(plan_s), centers=centers)
        want = [first_fault(serial, r) for r in reqs]

        # Batched: per block, wants in admission order (the Rust want list),
        # errors pinned to their request.
        _, batched = make_world(FaultPlan.parse(plan_s), centers=centers)
        outcomes = [["ok", None] for _ in reqs]
        for b in range(n_blocks):
            for i, r in enumerate(reqs):
                for e in r.get(b, ()):
                    kind, payload = batched.serve(b, e)
                    if kind == "error" and outcomes[i][1] is None:
                        outcomes[i] = ["error", payload]
                    elif kind == "degraded" and outcomes[i][0] == "ok":
                        outcomes[i][0] = "degraded"
        got = [tuple(o) for o in outcomes]
        assert want == got, (
            f"trial {trial}: attribution diverged\n  serial  {want}\n  batched {got}")
        assert serial.m == batched.m, f"trial {trial}: fault metrics diverged"
    print(f"[ok] attribution parity: {trials} randomized workloads pin "
          "identical per-request outcomes serial vs batched")


# ------------------------------------------------------ admission control

def check_admission_control():
    rng = random.Random(0xFA04)
    trials = 300
    for trial in range(trials):
        max_queue = rng.choice([0, 1, 2, 4])
        deadline_us = rng.choice([0, 300, 2_000])
        n = rng.randint(4, 24)
        depth, shed, answered, executed = 0, 0, 0, 0
        queue = []  # (request id, submit time)
        now = 0
        events = (["submit"] * n) + (["drain"] * rng.randint(1, n))
        rng.shuffle(events)
        rid = 0
        for ev in events:
            now += rng.randint(0, 500)
            if ev == "submit":
                if max_queue and depth >= max_queue:
                    shed += 1
                    answered += 1  # Overloaded(queue full), immediately
                else:
                    depth += 1
                    queue.append((rid, now))
                rid += 1
            else:  # worker drains one window
                window, queue = queue[:8], queue[8:]
                depth -= len(window)
                for _, submitted in window:
                    if deadline_us and now - submitted > deadline_us:
                        shed += 1  # Overloaded(deadline exceeded)
                    else:
                        executed += 1
                    answered += 1
        # Shutdown drains the remainder (close flush ignores linger).
        now += 1_000
        for _, submitted in queue:
            depth -= 1
            if deadline_us and now - submitted > deadline_us:
                shed += 1
            else:
                executed += 1
            answered += 1
        assert answered == n, f"trial {trial}: {answered} answers for {n} submits"
        assert depth == 0, f"trial {trial}: depth gauge leaked ({depth})"
        assert executed + shed == n
        if max_queue == 0 and deadline_us == 0:
            assert shed == 0, "no admission knobs -> no shedding"
    print(f"[ok] admission control: {trials} random schedules answer every "
          "request exactly once; depth gauge returns to zero")


if __name__ == "__main__":
    check_plan_replay()
    check_retry_convergence()
    check_quarantine_lifecycle()
    check_attribution_parity()
    check_admission_control()
    print("sim_faults: ALL CHECKS PASSED")
    sys.exit(0)
