#!/usr/bin/env python3
"""Seeded validation harness for PR 5 (SIMD microkernel layer).

The container has no Rust toolchain, so this script validates the three
load-bearing numerical claims of `rust/src/tensor/{kernel,simd}.rs` against
faithful Python ports in exact float32 arithmetic (FMA emulated through
float64 intermediates, which is exact for f32 products):

1. **vexp accuracy** — the Cephes-style polynomial `exp` used by the SIMD
   silu/softmax tier: max relative error vs the true exp over the clamped
   domain must be < 1e-6 (the Rust props then allow 1e-5 end to end), with
   exact values at 0 and finite saturation at the clamp edges.

2. **GEMM driver blocking** — the packed-panel k-panel/j-tile/zero-padded
   micropanel index structure of the AVX2 `matmul_nt` driver, replayed
   per-element in f32: must match numpy within 1e-5 relative across ragged
   shapes that straddle every tile edge (6-row, 16-col, 64-NC, 256-KC).

3. **Row independence, bit for bit** — the per-element fold of the SIMD
   kernels depends only on (k-extent, column): replaying the same structure
   over concat(A1, A2) and over the pieces must agree EXACTLY (f32 bit
   equality), including ragged row tails (7 = 6+1 vs 4+3 splits) and the
   8-lane CSR SpMM batch tiles. This is the micro-theorem behind
   batched==serial / store==monolithic parity under the SIMD kernels.
"""

import numpy as np

f32 = np.float32
f64 = np.float64

KC, NC, NR = 256, 64, 16  # k-panel, packed-panel width, micropanel lanes


def fma(a, b, c):
    """round_f32(a*b + c): f32 FMA emulated via f64 (product is exact)."""
    return f32(f64(a) * f64(b) + f64(c))


# ----------------------------------------------------------------- 1. vexp

LOG2E = f32(1.4426950408889634)
LN2_HI = f32(0.693359375)
LN2_LO = f32(-2.12194440e-4)
POLY = [f32(c) for c in (1.98756915e-4, 1.39819995e-3, 8.3334519e-3,
                         4.1665796e-2, 1.66666655e-1, 5.00000012e-1)]


def vexp(x):
    """Exact f32 replay of simd::vexp (vectorized over a numpy array)."""
    x = np.clip(f32(x), f32(-87.33655), f32(88.37626))
    n = np.rint(f64(f32(x * LOG2E))).astype(np.int32)  # cvtps_epi32: round-even
    fx = f32(n)
    r = fma(-fx, LN2_HI, x)
    r = fma(-fx, LN2_LO, r)
    r2 = f32(r * r)
    p = np.full_like(r, POLY[0])
    for c in POLY[1:]:
        p = fma(p, r, np.full_like(r, c))
    y = f32(fma(p, r2, r) + f32(1.0))
    pow2 = np.ascontiguousarray((n.astype(np.int32) + 127) << 23).view(np.float32)
    return f32(y * pow2)


def check_vexp():
    xs = f32(np.linspace(-87.0, 88.0, 2_000_001))
    got = vexp(xs).astype(f64)
    want = np.exp(xs.astype(f64))
    rel = np.abs(got - want) / want
    assert rel.max() < 1e-6, f"vexp max rel err {rel.max():.3e}"
    assert vexp(f32(0.0)) == f32(1.0), "exp(0) must be exactly 1"
    assert np.isfinite(vexp(f32(1e30))), "upper clamp must stay finite"
    assert vexp(f32(-1e30)) > 0, "lower clamp must stay positive"
    # silu at extremes through this exp: finite, saturating.
    for x in (f32(-100.0), f32(100.0)):
        s = f32(x / (f32(1.0) + vexp(f32(-x))))
        assert np.isfinite(s), f"silu({x}) = {s}"
    print(f"  [1] vexp: max rel err {rel.max():.2e} over [-87, 88] "
          f"({len(xs):,} points), exp(0)==1, clamps finite")


# ------------------------------------------- 2./3. GEMM NT panel structure


def gemm_nt_sim(a, bt):
    """Per-element replay of the AVX2 gemm_nt fold: k-panels of KC in
    order, FMA chain per panel, one add into C per panel. The j/row tiling
    only selects WHICH elements a microkernel instance computes — each
    lane's arithmetic is this fold — so simulating per element is faithful.
    """
    m, k = a.shape
    n = bt.shape[0]
    c = np.zeros((m, n), dtype=f32)
    for i in range(m):
        for j in range(n):
            total = f32(0.0)
            for kb in range(0, max(k, 1), KC):
                kw = min(KC, k - kb)
                acc = f32(0.0)
                for kk in range(kw):
                    acc = fma(a[i, kb + kk], bt[j, kb + kk], acc)
                total = f32(total + acc)
            c[i, j] = total
    return c


def spmm_nt_sim(values, col_idx, row_ptr, x):
    """Per-element replay of the CSR SpMM tile fold (strict index order,
    one add into out). Lanes are batch rows; padding lanes are zeros and
    never feed other lanes."""
    b, n_rows = x.shape[0], len(row_ptr) - 1
    out = np.zeros((b, n_rows), dtype=f32)
    for bi in range(b):
        for r in range(n_rows):
            lo, hi = row_ptr[r], row_ptr[r + 1]
            if lo == hi:
                continue
            acc = f32(0.0)
            for i in range(lo, hi):
                acc = fma(values[i], x[bi, col_idx[i]], acc)
            out[bi, r] = f32(out[bi, r] + acc)
    return out


def check_gemm_blocking():
    rng = np.random.default_rng(0)
    shapes = [(1, 1, 1), (5, 15, 31), (6, 16, 64), (7, 17, 65),
              (3, 63, 255), (4, 65, 257), (2, 130, 300), (13, 40, 256)]
    for m, n, k in shapes:
        a = f32(rng.standard_normal((m, k)))
        bt = f32(rng.standard_normal((n, k)))
        got = gemm_nt_sim(a, bt).astype(f64)
        want = a.astype(f64) @ bt.astype(f64).T
        denom = max(np.linalg.norm(want), 1.0)
        err = np.linalg.norm(got - want) / denom
        assert err < 1e-5, f"gemm_nt sim {m}x{k}@({n}x{k})^T rel err {err:.2e}"
    print(f"  [2] gemm_nt panel fold matches numpy over {len(shapes)} ragged shapes")


def check_row_independence():
    rng = np.random.default_rng(1)
    # GEMM: 7 rows = 6+1 microkernel split vs 4+3 request split.
    bt = f32(rng.standard_normal((37, 29)))
    x = f32(rng.standard_normal((7, 29)))
    full = gemm_nt_sim(x, bt)
    for split in (1, 2, 3, 4, 5, 6):
        parts = np.vstack([gemm_nt_sim(x[:split], bt), gemm_nt_sim(x[split:], bt)])
        assert (full.view(np.uint32) == parts.view(np.uint32)).all(), \
            f"gemm rows depend on batch split at {split}"
    # CSR: ragged 8-lane tiles (9 rows = 8+1 vs 5+4).
    dense = f32(rng.standard_normal((12, 10)))
    dense[f32(rng.random((12, 10))) > 0.3] = 0
    values, col_idx, row_ptr = [], [], [0]
    for r in range(12):
        for c in range(10):
            if dense[r, c] != 0:
                values.append(dense[r, c])
                col_idx.append(c)
        row_ptr.append(len(values))
    xb = f32(rng.standard_normal((9, 10)))
    sfull = spmm_nt_sim(values, col_idx, row_ptr, xb)
    for split in (1, 4, 5, 8):
        parts = np.vstack([spmm_nt_sim(values, col_idx, row_ptr, xb[:split]),
                           spmm_nt_sim(values, col_idx, row_ptr, xb[split:])])
        assert (sfull.view(np.uint32) == parts.view(np.uint32)).all(), \
            f"spmm rows depend on batch split at {split}"
    # Elementwise tier: vexp is per-element, so any row split is trivially
    # bit-stable as long as tails are padded (the Rust rows pad to 8 lanes
    # with values that are computed then DISCARDED); emulate a 13-wide row
    # processed as 8 + padded-5 vs direct.
    row = f32(rng.standard_normal(13) * 3)
    direct = vexp(row)
    padded_tail = vexp(np.concatenate([row[8:], np.zeros(3, dtype=f32)]))[:5]
    tiled = np.concatenate([vexp(row[:8]), padded_tail])
    assert (direct.view(np.uint32) == tiled.view(np.uint32)).all(), \
        "padded-tail vexp must equal full-width vexp per element"
    print("  [3] bit-exact row independence: gemm splits 1..6 of 7, "
          "spmm splits over 8-lane tiles, padded elementwise tails")


def main():
    print("sim_simd: validating SIMD kernel numerics (no-toolchain fallback)")
    check_vexp()
    check_gemm_blocking()
    check_row_independence()
    print("sim_simd OK")


if __name__ == "__main__":
    main()
