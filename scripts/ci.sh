#!/usr/bin/env bash
# Tier-1 CI for the resmoe repo: release build, full test suite, and a fast
# perf smoke that exercises BOTH the serial path (RESMOE_THREADS=1) and the
# persistent worker pool (RESMOE_THREADS=2). Run from anywhere.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== build (release) =="
cargo build --release

echo "== tests (runtime kernel: AVX2 where the CPU has it) =="
cargo test -q

echo "== tests (scalar twin, RESMOE_SIMD=0) =="
# The SIMD kill-switch pass: the portable scalar kernels must stay green,
# and the serving bit-parity suites (batched==serial, store==monolithic,
# concurrent==serial) re-pin under BOTH kernels across the two runs.
RESMOE_SIMD=0 cargo test -q

echo "== tests (serial kernels, RESMOE_THREADS=1) =="
RESMOE_THREADS=1 cargo test -q --lib tensor

echo "== perf smoke (pooled, RESMOE_THREADS=2) =="
RESMOE_THREADS=2 cargo bench --bench perf_hotpath -- --fast

echo "== pack → serve-packed round-trip smoke =="
PACK_DIR=$(mktemp -d)
trap 'rm -rf "$PACK_DIR"' EXIT
cargo run --release --quiet -- pack --model switch-mini-8 --method resmoe-up \
  --rate 0.25 --layers 1 --seed 0 --out "$PACK_DIR/model.rmes"
cargo run --release --quiet -- serve-packed --artifact "$PACK_DIR/model.rmes" \
  --requests 16 --cache-mb 1 --workers 2

echo "== continuous-batching smoke (env-tuned windows, 1 worker) =="
# One worker + a wide window forces real multi-request batches; the
# batch_summary line in the demo output carries occupancy/flush counters.
RESMOE_BATCH=4 RESMOE_LINGER_US=2000 cargo run --release --quiet -- serve-packed \
  --artifact "$PACK_DIR/model.rmes" --requests 24 --cache-mb 4 --workers 1

echo "== observability: overhead smoke + snapshot-diff SLO gate =="
# Same packed workload twice — production default (RESMOE_TRACE=0) vs
# tracing to a JSONL file — each exporting its registry snapshot. The gate
# (scripts/check_obs.py) enforces: tracing-off tok/s within 3% of traced
# (the disabled hot path is a few relaxed atomics), SLO floors on p99 /
# tok/s / hit-rate / prefetch-useful-rate, one well-nested trace line per
# request attributing >= 95% of request wall time to named stages, and an
# identical instrument schema across runs → reports/BENCH_obs.json.
RESMOE_TRACE=0 cargo run --release --quiet -- serve-packed \
  --artifact "$PACK_DIR/model.rmes" --requests 32 --cache-mb 4 --workers 2 \
  --metrics-out "$PACK_DIR/obs_off.json"
RESMOE_TRACE="$PACK_DIR/trace.jsonl" cargo run --release --quiet -- serve-packed \
  --artifact "$PACK_DIR/model.rmes" --requests 32 --cache-mb 4 --workers 2 \
  --metrics-out "$PACK_DIR/obs_on.json"
RESMOE_SLO_P99_MS=2000 RESMOE_SLO_TOKS=100 RESMOE_SLO_HIT_RATE=0.10 \
  python3 scripts/check_obs.py \
  "$PACK_DIR/obs_off.json" "$PACK_DIR/obs_on.json" "$PACK_DIR/trace.jsonl"

echo "== chaos smoke: converging transient storm under RESMOE_FAULTS =="
# Same packed workload as the observability baseline, but with a seeded
# deterministic fault plan injecting two transient read errors per shard
# target — strictly fewer than the cache's 3-retry budget, so every fetch
# converges inside its singleflight and the demo's zero-Response::Error
# check must still pass. The gate (scripts/check_faults.py) then audits
# the fault counters against the clean obs baseline: storm fired, every
# transient retried, zero quarantines/degraded serves/sheds, tail latency
# bounded, identical instrument schema → reports/BENCH_faults.json.
RESMOE_TRACE=0 RESMOE_FAULTS="seed:7,spec:transient@store.read*2" \
  cargo run --release --quiet -- serve-packed \
  --artifact "$PACK_DIR/model.rmes" --requests 32 --cache-mb 4 --workers 2 \
  --metrics-out "$PACK_DIR/faults_chaos.json"
python3 scripts/check_faults.py "$PACK_DIR/obs_off.json" "$PACK_DIR/faults_chaos.json"

echo "== int8 quantized pack → serve-packed smoke =="
# Quantized residual tier: pack with --quantize int8 (RMES v2, q8-* shard
# kinds) and serve it twice — once on the runtime kernel, once with the
# SIMD kill-switch so the scalar dequant-fused twins cover the same path.
cargo run --release --quiet -- pack --model switch-mini-8 --method resmoe-up \
  --rate 0.25 --layers 1 --seed 0 --quantize int8 --out "$PACK_DIR/model-q8.rmes"
cargo run --release --quiet -- serve-packed --artifact "$PACK_DIR/model-q8.rmes" \
  --requests 16 --cache-mb 1 --workers 2
RESMOE_SIMD=0 cargo run --release --quiet -- serve-packed \
  --artifact "$PACK_DIR/model-q8.rmes" --requests 16 --cache-mb 1 --workers 2

echo "== traffic scenarios: loadgen sweep + replay-identity gate =="
# The seeded scenario harness over the quantized artifact (so the cache
# decisions exercise the int8 residual tier): one sweep at --vworkers 4,
# one replay at --vworkers 1 under the SAME seed. The gate
# (scripts/check_scenarios.py) enforces bit-identical schedule/response/
# counter fingerprints across the two (fixed seed + worker invariance),
# zero errors/degraded, sheds only in slow_reader, counter conservation,
# and super-proportional top-decile expert skew in the zipf scenarios
# -> reports/BENCH_scenarios.json. The set now includes gen_storm — the
# closed-loop decode-heavy storm that drives the iteration-level decode
# batcher inside the engine. BENCHMARKS.md then re-renders every
# reports/BENCH_*.json produced above.
cargo run --release --quiet -- loadgen --artifact "$PACK_DIR/model-q8.rmes" \
  --scenario all --seed 7 --vworkers 4 --cache-mb 1 \
  --out "$PACK_DIR/scenarios_run.json"
cargo run --release --quiet -- loadgen --artifact "$PACK_DIR/model-q8.rmes" \
  --scenario all --seed 7 --vworkers 1 --cache-mb 1 \
  --out "$PACK_DIR/scenarios_replay.json"
python3 scripts/check_scenarios.py \
  "$PACK_DIR/scenarios_run.json" "$PACK_DIR/scenarios_replay.json"

echo "== decode continuous batching: relaxed-parity sim + throughput gate =="
# Seeded sequential-vs-batched decode simulation (scheduler conservation,
# bit-parity in the order-independent budget regimes, logit rel-err bound,
# KV page-pool accounting, >= 2x batched tok/s at 8 clients) -> the gate
# (scripts/check_decode.py) pins all of it from reports/BENCH_decode.json.
python3 scripts/sim_decode.py
python3 scripts/check_decode.py reports/BENCH_decode.json
python3 scripts/benchmarks_md.py

echo "== batching scheduler/parity simulation (no-toolchain fallback validator) =="
python3 scripts/sim_batching.py

echo "== SIMD kernel numerics simulation (no-toolchain fallback validator) =="
python3 scripts/sim_simd.py

echo "== int8 quantization numerics simulation (no-toolchain fallback validator) =="
python3 scripts/sim_quant.py

echo "== observability invariants simulation (no-toolchain fallback validator) =="
python3 scripts/sim_obs.py

echo "== fault-injection state-machine simulation (no-toolchain fallback validator) =="
python3 scripts/sim_faults.py

echo "== loadgen schedule/replay simulation (no-toolchain fallback validator) =="
# Line-for-line Python replica of rust/src/loadgen/{scenario,schedule}.rs:
# must reproduce the Rust schedules bit-for-bit (check_scenarios.py
# cross-checks the fingerprints when both implementations ran).
python3 scripts/sim_loadgen.py --no-report

echo "CI OK"
