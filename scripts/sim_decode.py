#!/usr/bin/env python3
"""Seeded decode-lane simulation: the relaxed-parity + throughput story
for iteration-level continuous batching (PR 10), runnable without a Rust
toolchain.

Model-checks three claims against Python ports of the Rust state machines:

1. **Scheduler conservation** (`coordinator/batcher.rs::DecodeScheduler`):
   randomized admission/step traces pin the token-bookkeeping laws —
   plan rows == active sequences in admission (ticket) order, every
   retirement produces exactly `min(max_new, max_seq - prompt_len)`
   tokens with `fed == prompt_len + max(produced, 1) - 1`, and
   `admitted == finished + active` after every step.

2. **Relaxed parity** (the `tests/prop_decode.rs` contract, quantified):
   a toy MoE decode model whose per-row math is order-independent but
   whose fused-vs-restore arm comes from a shared stateful cost model
   (capacity + heat + LRU, as in `coordinator/cache.rs`). Sequential
   (request-major) and batched (step-major, via the scheduler) runs must
   be **bit-identical in the order-independent regimes** (roomy budget =
   all-restore, zero budget = all-fused) including greedy token
   sequences; under order-sensitive intermediate budgets the per-token
   logit relative error against the sequential reference must stay under
   the fused-approximation bound (each fused serve perturbs logits by
   <= EPS relatively, so rows with identical context differ by
   O(layers * EPS)).

3. **Decode throughput**: a virtual-clock cost model
   (`step_us = base + per_row * rows`, the loadgen ServiceModel shape)
   over 8 concurrent Generate clients. Batching amortizes the per-step
   base across up to 8 rows, so batched decode tok/s must be >= 2x the
   one-at-a-time sequential lane — the acceptance floor `check_decode.py`
   gates. KV page leases (16-token pages) are charged per admitted
   sequence and must conserve: granted == released, pool drained at the
   end; a tight-pool variant pins refusal accounting
   (batched + solo == total, refusals == solos).

Writes `reports/BENCH_decode.json` (source "python-sim") unless
--no-report is given.

Usage: sim_decode.py [--seed N] [--no-report]
"""

import json
import os
import random
import sys

MASK = (1 << 64) - 1
FNV_OFFSET = 0xCBF29CE484222325
FNV_PRIME = 0x100000001B3

# Toy-model geometry (small enough to run thousands of steps, large
# enough that argmax is never degenerate).
VOCAB = 32
LAYERS = 4
SLOTS = 8
HOT_ACCESSES = 3
EPS = 1e-3  # relative perturbation of one fused serve
MAX_SEQ = 64

# Virtual-clock decode cost model (ServiceModel shape): one batched model
# step costs base + per_row * rows, so the base amortizes across rows.
STEP_BASE_US = 300
STEP_PER_ROW_US = 40


def fnv_mix(*vals):
    h = FNV_OFFSET
    for v in vals:
        for b in (v & MASK).to_bytes(8, "little"):
            h = ((h ^ b) * FNV_PRIME) & MASK
    return h


def unit(h):
    """u64 hash -> float in [0, 1)."""
    return (h >> 11) / float(1 << 53)


# ------------------------------------------------------------- scheduler
# Port of coordinator/batcher.rs::DecodeScheduler.


class DecodeScheduler:
    def __init__(self, max_batch):
        self.max_batch = max(1, max_batch)
        self.seqs = []  # dicts: ticket/prompt/max_new/max_seq/fed/produced
        self.next_ticket = 0
        self.admitted = 0
        self.finished = 0
        self.steps = 0
        self.tokens_fed = 0

    def has_room(self):
        return len(self.seqs) < self.max_batch

    def active(self):
        return len(self.seqs)

    def is_idle(self):
        return not self.seqs

    def admit(self, prompt, max_new, max_seq):
        assert self.has_room() and prompt and len(prompt) < max_seq
        ticket = self.next_ticket
        self.next_ticket += 1
        self.admitted += 1
        self.seqs.append(dict(ticket=ticket, prompt=list(prompt),
                              max_new=max_new, max_seq=max_seq,
                              fed=0, produced=[]))
        return ticket

    def plan(self):
        out = []
        for s in self.seqs:
            tok = (s["prompt"][s["fed"]] if s["fed"] < len(s["prompt"])
                   else s["produced"][-1])
            out.append((s["ticket"], tok))
        return out

    def record(self, logits):
        assert len(logits) == len(self.seqs)
        self.steps += 1
        self.tokens_fed += len(logits)
        done, keep = [], []
        for s, lg in zip(self.seqs, logits):
            s["fed"] += 1
            retire = False
            if s["fed"] >= len(s["prompt"]):
                k = len(s["produced"])
                if k < s["max_new"] and len(s["prompt"]) + k < s["max_seq"]:
                    s["produced"].append(argmax_last(lg))
                    k = len(s["produced"])
                    retire = (k >= s["max_new"]
                              or len(s["prompt"]) + k >= s["max_seq"])
                else:
                    retire = True
            (done if retire else keep).append(s)
        self.seqs = keep
        self.finished += len(done)
        return done


def argmax_last(row):
    """Greedy argmax with LAST-index tie-break — the `max_by` fold both
    Model::generate and DecodeScheduler::record use."""
    best, arg = row[0], 0
    for i, v in enumerate(row):
        if v >= best:
            best, arg = v, i
    return arg


def check_scheduler_conservation(seed, cases=200):
    rng = random.Random(seed)
    violations = 0
    for _ in range(cases):
        max_batch = rng.randint(1, 4)
        max_seq = rng.randint(6, 11)
        pending = [([rng.randrange(VOCAB) for _ in range(rng.randint(1, 5))],
                    rng.randint(0, 5))
                   for _ in range(rng.randint(1, 12))]
        pending = [(p, m) for p, m in pending if len(p) < max_seq]
        sched = DecodeScheduler(max_batch)
        expected = 0
        retired = []
        while pending or not sched.is_idle():
            while (pending and sched.has_room()
                   and (sched.is_idle() or rng.random() < 0.7)):
                p, m = pending.pop(0)
                sched.admit(p, m, max_seq)
                expected += 1
            plan = sched.plan()
            ok = (len(plan) == sched.active()
                  and all(a < b for (a, _), (b, _)
                          in zip(plan, plan[1:])))
            rows = [[unit(fnv_mix(t, k, v)) for v in range(VOCAB)]
                    for k, (t, _) in enumerate(plan)]
            for f in sched.record(rows):
                want = min(f["max_new"], max_seq - len(f["prompt"]))
                ok = ok and len(f["produced"]) == want
                ok = ok and (f["fed"] == len(f["prompt"])
                             + max(len(f["produced"]), 1) - 1)
                retired.append(f)
            ok = ok and sched.admitted == sched.finished + sched.active()
            if not ok:
                violations += 1
        if not (sched.is_idle() and len(retired) == expected
                and sched.tokens_fed == sum(f["fed"] for f in retired)):
            violations += 1
    return cases, violations


# ------------------------------------------------------------ toy decode
# Row math is a pure function of the sequence's own token history; only
# the fused/restore arm comes from shared state — exactly the relaxed-
# parity structure of the Rust engine.


class ServeState:
    """Order-sensitive per-layer cost model: capacity + heat + LRU.
    serve() returns True when the exact (restore) arm runs."""

    def __init__(self, cap):
        self.cap = cap
        self.resident = [dict() for _ in range(LAYERS)]  # slot -> last_used
        self.heat = [dict() for _ in range(LAYERS)]
        self.clock = 0
        self.fused = 0
        self.restored = 0

    def serve(self, layer, slot):
        self.clock += 1
        res, heat = self.resident[layer], self.heat[layer]
        heat[slot] = heat.get(slot, 0) + 1
        if slot in res:
            res[slot] = self.clock
            self.restored += 1
            return True
        if self.cap == 0:
            self.fused += 1
            return False
        if len(res) >= self.cap:
            if heat[slot] < HOT_ACCESSES:
                self.fused += 1
                return False
            victim = min(res, key=res.get)
            del res[victim]
        res[slot] = self.clock
        self.restored += 1
        return True


def route(tok, layer):
    return fnv_mix(0xE0, tok, layer) % SLOTS


def base_logits(seed, hist):
    h = fnv_mix(seed, len(hist), *hist)
    return [unit(fnv_mix(h, v)) * 2.0 - 1.0 for v in range(VOCAB)]


def model_step(seed, hist, state):
    """One decode step: feed hist[-1], return logits for the next token.
    Fused serves perturb each logit by a seeded factor <= EPS relative —
    the bounded residual-approximation arm."""
    row = base_logits(seed, hist)
    t = hist[-1]
    for layer in range(LAYERS):
        slot = route(t, layer)
        if not state.serve(layer, slot):
            for v in range(VOCAB):
                d = (unit(fnv_mix(0xF0, layer, slot, v)) * 2.0 - 1.0) * EPS
                row[v] *= 1.0 + d
    return row


def decode_sequential(seed, reqs, cap):
    """Request-major reference: each request decodes start-to-finish,
    including the serial lane's wasted final-token step (it feeds the
    last produced token and discards the logits — mutating the shared
    cost model exactly as Model::generate does)."""
    state = ServeState(cap)
    out = []
    for prompt, max_new in reqs:
        toks = list(prompt)
        rows = []
        want = min(max_new, MAX_SEQ - len(prompt))
        for fed in range(len(prompt) + want):
            row = model_step(seed, toks[:fed + 1], state)
            if fed >= len(prompt) - 1 and len(toks) - len(prompt) < want:
                rows.append(row)
                toks.append(argmax_last(row))
        out.append((toks[len(prompt):], rows))
    return out, state


def decode_batched(seed, reqs, cap, max_batch):
    """Step-major lane: the scheduler interleaves sequences; per-row math
    is unchanged, only the shared cost model sees a different serve
    order. Skips the wasted final-token step."""
    state = ServeState(cap)
    sched = DecodeScheduler(max_batch)
    pending = list(range(len(reqs)))
    by_ticket = {}
    rows_by_req = [[] for _ in reqs]
    out = [None] * len(reqs)
    while pending or not sched.is_idle():
        while pending and sched.has_room():
            i = pending.pop(0)
            prompt, max_new = reqs[i]
            by_ticket[sched.admit(prompt, max_new, MAX_SEQ)] = i
        plan = sched.plan()
        rows = []
        for s, _ in zip(sched.seqs, plan):
            hist = (list(s["prompt"]) + s["produced"])[:s["fed"] + 1]
            rows.append(model_step(seed, hist, state))
        for s, row in zip(list(sched.seqs), rows):
            if s["fed"] + 1 >= len(s["prompt"]):
                rows_by_req[by_ticket[s["ticket"]]].append(row)
        for f in sched.record(rows):
            i = by_ticket[f["ticket"]]
            out[i] = (f["produced"], rows_by_req[i][:len(f["produced"])])
    return out, state


def rel_err(a, b):
    scale = max(max(abs(x) for x in b), 1e-12)
    return max(abs(x - y) for x, y in zip(a, b)) / scale


def check_parity(seed):
    rng = random.Random(seed)
    reqs = [([rng.randrange(VOCAB) for _ in range(rng.randint(2, 6))],
             rng.randint(1, 6))
            for _ in range(8)]
    results = {}
    # Order-independent regimes: bit-identical, greedy sequences equal.
    for label, cap in (("roomy", 10 ** 9), ("zero", 0)):
        want, _ = decode_sequential(seed, reqs, cap)
        got, _ = decode_batched(seed, reqs, cap, 4)
        match = all(g[0] == w[0] and g[1] == w[1]
                    for g, w in zip(got, want))
        results[f"greedy_match_{label}"] = match
    # Order-sensitive regime: rel-err bound on rows with shared context.
    max_err, compared, divergences = 0.0, 0, 0
    for cap in (1, 2, 3):
        want, ss = decode_sequential(seed, reqs, cap)
        got, bs = decode_batched(seed, reqs, cap, 4)
        order_sensitive = (ss.fused, ss.restored) != (bs.fused, bs.restored)
        for (gt, gr), (wt, wr) in zip(got, want):
            for k, (a, b) in enumerate(zip(gr, wr)):
                if gt[:k] != wt[:k]:
                    divergences += 1
                    break
                max_err = max(max_err, rel_err(a, b))
                compared += 1
        results.setdefault("order_sensitive_caps", 0)
        results["order_sensitive_caps"] += int(order_sensitive)
    results["max_rel_err"] = max_err
    results["rows_compared"] = compared
    results["greedy_divergences"] = divergences
    # The theoretical bound: every fused serve perturbs by <= EPS per
    # layer, both arms, so rows over one shared context differ by at most
    # (1 + EPS)^(2 * LAYERS) - 1 (plus fp noise).
    results["rel_err_bound"] = (1.0 + EPS) ** (2 * LAYERS) - 1.0 + 1e-9
    return results


# ------------------------------------------------------------ throughput

KV_PAGE_TOKENS = 16


def kv_pages(prompt_len, max_new):
    return -(-min(prompt_len + max_new, MAX_SEQ) // KV_PAGE_TOKENS)


def run_throughput(seed, clients=8, requests=32, pool_pages=None):
    """Virtual-clock decode: `requests` Generates offered by `clients`
    concurrent slots. Sequential lane serves one at a time (each fed
    token pays the full step base, including the wasted final step);
    batched lane packs up to `clients` rows per step. Returns both
    lanes' stats plus KV-pool conservation counters."""
    rng = random.Random(seed)
    reqs = [(rng.randint(4, 12), rng.randint(8, 16)) for _ in range(requests)]

    seq_us = 0
    produced = 0
    for p, m in reqs:
        want = min(m, MAX_SEQ - p)
        seq_us += (p + want) * (STEP_BASE_US + STEP_PER_ROW_US)
        produced += want
    sequential = {
        "tok_s": produced * 1e6 / seq_us,
        "tokens": produced,
        "makespan_ms": seq_us / 1000.0,
    }

    pool = dict(pages=pool_pages, used=0, peak=0, granted=0, released=0,
                refusals=0)
    sched = DecodeScheduler(clients)
    pending = list(reqs)
    leases = {}  # ticket -> pages
    bat_us = 0
    steps = 0
    rows_fed = 0
    solo = 0
    while pending or not sched.is_idle():
        while pending and sched.has_room():
            p, m = pending[0]
            need = kv_pages(p, m)
            if (pool["pages"] is not None
                    and pool["used"] + need > pool["pages"]
                    and pool["used"] > 0):
                pool["refusals"] += 1
                solo += 1
                pending.pop(0)
                want = min(m, MAX_SEQ - p)
                bat_us += (p + want) * (STEP_BASE_US + STEP_PER_ROW_US)
                continue
            pool["granted"] += 1
            pool["used"] += need
            pool["peak"] = max(pool["peak"], pool["used"])
            pending.pop(0)
            t = sched.admit(list(range(p)), m, MAX_SEQ)
            leases[t] = need
        if sched.is_idle():
            continue
        plan = sched.plan()
        bat_us += STEP_BASE_US + STEP_PER_ROW_US * len(plan)
        steps += 1
        rows_fed += len(plan)
        rows = [[unit(fnv_mix(seed, t, k, v)) for v in range(VOCAB)]
                for k, (t, _) in enumerate(plan)]
        for f in sched.record(rows):
            pool["used"] -= leases.pop(f["ticket"])
            pool["released"] += 1
    batched = {
        "tok_s": produced * 1e6 / bat_us,
        "tokens": produced,
        "makespan_ms": bat_us / 1000.0,
        "steps": steps,
        "mean_step_batch": rows_fed / steps if steps else 0.0,
        "solo_fallbacks": solo,
    }
    conserved = (pool["used"] == 0
                 and pool["granted"] == pool["released"]
                 and sched.admitted + solo == requests
                 and pool["refusals"] == solo)
    return sequential, batched, pool, conserved


# ----------------------------------------------------------------- main


def check(name, ok, detail=""):
    print(f"  {'PASS' if ok else 'FAIL'}  {name}"
          + (f": {detail}" if detail else ""))
    return ok


def main():
    seed = 7
    write_report = True
    args = sys.argv[1:]
    while args:
        a = args.pop(0)
        if a == "--seed":
            seed = int(args.pop(0))
        elif a == "--no-report":
            write_report = False
        else:
            sys.exit(f"usage: {sys.argv[0]} [--seed N] [--no-report]")

    failures = 0

    cases, violations = check_scheduler_conservation(seed)
    failures += not check(
        f"scheduler conservation over {cases} randomized traces",
        violations == 0, f"{violations} violation(s)")

    parity = check_parity(seed)
    failures += not check("roomy budget: batched == sequential bitwise",
                          parity["greedy_match_roomy"])
    failures += not check("zero budget: batched == sequential bitwise",
                          parity["greedy_match_zero"])
    failures += not check(
        "intermediate budgets are order-sensitive (the relaxed regime)",
        parity["order_sensitive_caps"] > 0,
        f"{parity['order_sensitive_caps']}/3 caps diverge in decisions")
    failures += not check(
        "per-token logit rel-err under the fused-approximation bound",
        parity["max_rel_err"] <= parity["rel_err_bound"],
        f"max {parity['max_rel_err']:.2e} <= {parity['rel_err_bound']:.2e} "
        f"over {parity['rows_compared']} rows")

    sequential, batched, pool, conserved = run_throughput(seed)
    speedup = batched["tok_s"] / sequential["tok_s"]
    failures += not check(
        "batched decode >= 2x sequential tok/s at 8 clients",
        speedup >= 2.0,
        f"{batched['tok_s']:.0f} vs {sequential['tok_s']:.0f} tok/s "
        f"({speedup:.2f}x, mean step batch "
        f"{batched['mean_step_batch']:.2f})")
    failures += not check("KV page pool conserves (roomy)", conserved,
                          f"granted {pool['granted']} == released "
                          f"{pool['released']}, used {pool['used']}")

    _, t_bat, t_pool, t_conserved = run_throughput(seed, pool_pages=6)
    failures += not check(
        "KV page pool conserves under refusals (tight, 6 pages)",
        t_conserved and t_pool["refusals"] > 0,
        f"{t_pool['refusals']} refusal(s) -> {t_bat['solo_fallbacks']} "
        f"solo fallback(s)")

    if write_report:
        os.makedirs("reports", exist_ok=True)
        doc = {
            "bench": "decode",
            "source": "python-sim",
            "seed": seed,
            "clients": 8,
            "decode_batch": 8,
            "kv_page_tokens": KV_PAGE_TOKENS,
            "sequential": sequential,
            "batched": batched,
            "speedup": speedup,
            "parity": parity,
            "scheduler": {"traces": cases, "violations": violations},
            "kv_pool": dict(pool, conserved=conserved),
            "kv_pool_tight": dict(t_pool, conserved=t_conserved),
        }
        with open("reports/BENCH_decode.json", "w") as f:
            json.dump(doc, f, indent=2)
            f.write("\n")
        print("  report -> reports/BENCH_decode.json (source python-sim)")

    if failures:
        sys.exit(f"sim_decode: {failures} check(s) failed")
    print("sim_decode OK")


if __name__ == "__main__":
    main()
