#!/usr/bin/env python3
"""Traffic-scenario CI gate: replay identity + per-scenario regression
pins over two `loadgen` benchmark documents.

Consumes two `loadgen --out` documents for the same seed — by convention
one run with `--vworkers 4` and one with `--vworkers 1` — and enforces:

1. **Replay identity / worker invariance** — every scenario's schedule,
   response, and counter fingerprints are bit-identical across the two
   runs: a fixed seed fully determines the traffic AND the serving
   decisions, regardless of the pool width.
2. **No surfaced failures** — zero `Response::Error` and zero degraded
   responses in every scenario (no faults are injected here).
3. **Sheds only where intended** — `slow_reader` must shed (its clients
   are built to back up against the depth cap and deadline); every other
   scenario must shed nothing.
4. **Counter conservation** — per scenario: executed + sheds ==
   arrivals; `server.requests` == executed and `server.shed` == sheds in
   the registry snapshots; cache misses are fully answered
   (`fused_serves + restore_serves + degraded_serves == misses`).
5. **Zipf skew reaches the experts** — in the zipf-routed scenarios the
   top-decile expert slots absorb >= `RESMOE_SCN_SKEW` (default 1.25x)
   their proportional share of serves.
6. **Schema parity** — every tenant snapshot in both documents exports
   identical instrument names.

Either document may instead be a `sim_loadgen.py` replica document
(`"source": "python-sim"`); engine-only gates (responses/counters/cache/
skew) are then skipped for the pairs involving it, but schedule
fingerprints must STILL match — that is the Rust-vs-Python
cross-implementation check.

Writes the run's per-scenario stats + gate outcomes to
`reports/BENCH_scenarios.json`. Exits non-zero on any failed gate.

Usage: check_scenarios.py RUN_JSON REPLAY_JSON
"""

import sys

from gatelib import GateSet, env_f, load_json, snapshot_schema

EXPECTED = ("zipf09", "zipf12", "bursty", "mixed", "slow_reader",
            "multi_tenant", "gen_storm")


def by_name(doc):
    return {s["scenario"]: s for s in doc["scenarios"]}


def tenant_counters(scenario):
    """Summed registry counters across the scenario's tenant snapshots
    (python-sim documents carry no snapshots -> empty)."""
    total = {}
    for td in scenario.get("tenants_detail") or []:
        for name, v in td["snapshot"]["counters"].items():
            total[name] = total.get(name, 0) + v
    return total


def main():
    if len(sys.argv) != 3:
        sys.exit(f"usage: {sys.argv[0]} RUN_JSON REPLAY_JSON")
    run = load_json(sys.argv[1])
    replay = load_json(sys.argv[2])

    gates = GateSet("check_scenarios")
    gate = gates.gate

    for doc, label in ((run, "run"), (replay, "replay")):
        gate(f"{label} is a scenarios bench", doc.get("bench") == "scenarios",
             f"bench={doc.get('bench')} source={doc.get('source')}")
    gate("seeds match", run.get("seed") == replay.get("seed"),
         f"run seed {run.get('seed')} vs replay seed {replay.get('seed')}")

    rs, ps = by_name(run), by_name(replay)
    gate("all canned scenarios present",
         set(EXPECTED) <= set(rs) and set(EXPECTED) <= set(ps),
         f"run has {sorted(rs)}")

    sim_involved = "python-sim" in (run.get("source"), replay.get("source"))
    skew_min = env_f("RESMOE_SCN_SKEW", 1.25)

    for name in EXPECTED:
        if name not in rs or name not in ps:
            continue
        a, b = rs[name], ps[name]

        # 1. Replay identity. Schedule fingerprints must agree even across
        # implementations; response/counter fingerprints only exist on
        # engine-backed (rust-loadgen) documents.
        fa, fb = a["fingerprints"], b["fingerprints"]
        gate(f"{name}: schedule fingerprint identical",
             fa["schedule"] == fb["schedule"],
             f"{fa['schedule']} vs {fb['schedule']}")
        for kind in ("responses", "counters"):
            if fa[kind] is not None and fb[kind] is not None:
                gate(f"{name}: {kind} fingerprint identical (vworkers "
                     f"{a.get('vworkers')} vs {b.get('vworkers')})",
                     fa[kind] == fb[kind], f"{fa[kind]} vs {fb[kind]}")

        # 2-4. Regression pins on the primary run.
        gate(f"{name}: no errors", a["errors"] == 0, f"{a['errors']} errors")
        gate(f"{name}: no degraded responses", a["degraded"] == 0,
             f"{a['degraded']} degraded")
        sheds = a["shed_admission"] + a["shed_deadline"]
        gate(f"{name}: conservation",
             a["executed"] + sheds == a["arrivals"],
             f"{a['executed']} executed + {sheds} shed == {a['arrivals']}")
        if name == "slow_reader":
            gate(f"{name}: sheds under backpressure",
                 0 < sheds < a["arrivals"],
                 f"{a['shed_admission']} admission + "
                 f"{a['shed_deadline']} deadline")
        else:
            gate(f"{name}: no sheds intended", sheds == 0, f"{sheds} shed")

        c = tenant_counters(a)
        if c:
            gate(f"{name}: server counters conserve",
                 c.get("server.requests", 0) == a["executed"]
                 and c.get("server.shed", 0) == sheds,
                 f"requests {c.get('server.requests', 0)} "
                 f"shed {c.get('server.shed', 0)}")
            answered = (c.get("cache.fused_serves", 0)
                        + c.get("cache.restore_serves", 0)
                        + c.get("cache.degraded_serves", 0))
            gate(f"{name}: cache misses fully answered",
                 answered == c.get("cache.misses", 0),
                 f"fused+restore+degraded {answered} vs "
                 f"misses {c.get('cache.misses', 0)}")

        # 5. Skew gate (engine-backed zipf scenarios only).
        if name in ("zipf09", "zipf12") and a.get("skew"):
            ratio = a["skew"]["ratio"]
            gate(f"{name}: expert-slot skew >= {skew_min:g}x proportional",
                 ratio >= skew_min,
                 f"top decile {a['skew']['top_decile_share']:.1%} of serves "
                 f"({ratio:.2f}x)")

    # 6. Schema parity across every tenant snapshot of both documents.
    schemas = set()
    snaps = 0
    for doc in (run, replay):
        for s in doc["scenarios"]:
            for td in s.get("tenants_detail") or []:
                schemas.add(repr(snapshot_schema(td)))
                snaps += 1
    if snaps:
        gate("instrument schema identical across all snapshots",
             len(schemas) == 1, f"{len(schemas)} schema(s) over {snaps}")
    elif not sim_involved:
        gate("snapshots present", False,
             "rust-loadgen documents carry no tenant snapshots")

    report = {
        "bench": "scenarios",
        "source": run.get("source"),
        "kernel": run.get("kernel"),
        "seed": run.get("seed"),
        "vworkers": run.get("vworkers"),
        "scenarios": run["scenarios"],
        "gates": {"skew_min": skew_min},
    }
    gates.write_report("scenarios", report)
    gates.finish()


if __name__ == "__main__":
    main()
