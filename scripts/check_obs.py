#!/usr/bin/env python3
"""Observability CI gate: tracing-overhead smoke + snapshot-diff SLO check.

Consumes two `--metrics-out` documents from the serving demo — one run with
`RESMOE_TRACE=0` (the production default) and one with tracing to a JSONL
file — plus that trace file, and enforces:

1. **Overhead** — the untraced run's tok/s must be within `RESMOE_OBS_TOL`
   (default 3%) of the traced run's: tracing off may never be slower than
   tracing on beyond noise, i.e. the disabled hot path really is a few
   relaxed atomic ops.
2. **SLOs** on the untraced run: p99 latency <= `RESMOE_SLO_P99_MS`,
   tok/s >= `RESMOE_SLO_TOKS`, cache hit-rate >= `RESMOE_SLO_HIT_RATE`,
   prefetch-useful-rate >= `RESMOE_SLO_PREFETCH_USEFUL`.
3. **Trace completeness** — the traced run emitted exactly one JSONL line
   per request; every line parses, spans nest by depth and stay within the
   measured wall, and named stages attribute >= `RESMOE_OBS_ATTR`
   (default 95%) of total request wall time.
4. **Snapshot schema diff** — both runs export the same counter/histogram
   instrument names (tracing must not change what is measured).

Writes the gate outcome and both runs' headline numbers to
`reports/BENCH_obs.json`. Exits non-zero on any failed gate.

Usage: check_obs.py OFF_METRICS_JSON ON_METRICS_JSON TRACE_JSONL
"""

import json
import sys

from gatelib import GateSet, env_f, load_json, snapshot_schema


def validate_line(line):
    """Shared invariant set (see scripts/sim_obs.py and rust/tests/prop_obs.rs).
    Returns (attributed_ns, wall_ns)."""
    j = json.loads(line)
    wall = j["wall_ns"]
    assert wall > 0, "zero-wall trace line"
    assert j["queue_ns"] <= wall, "queue beyond wall"
    spans = j["spans"]
    assert spans, "traced request with no spans"
    covered = 0
    for s in spans:
        assert s["t0"] + s["dur"] <= wall + 1, f"span {s['stage']} beyond wall"
        if s["depth"] > 0:
            assert any(p["depth"] == s["depth"] - 1
                       and p["t0"] <= s["t0"]
                       and p["t0"] + p["dur"] >= s["t0"] + s["dur"]
                       for p in spans), f"orphan depth-{s['depth']} span {s['stage']}"
        if s["depth"] == 0:
            covered += s["dur"]
    assert covered <= wall + 1, "depth-0 spans exceed wall"
    return covered, wall


def main():
    if len(sys.argv) != 4:
        sys.exit(f"usage: {sys.argv[0]} OFF_METRICS_JSON ON_METRICS_JSON TRACE_JSONL")
    off_path, on_path, trace_path = sys.argv[1:4]
    off = load_json(off_path)
    on = load_json(on_path)

    gates = GateSet("check_obs")
    gate = gates.gate

    gate("run identity", off["traced"] is False and on["traced"] is True,
         f"off.traced={off['traced']} on.traced={on['traced']}")

    tol = env_f("RESMOE_OBS_TOL", 0.03)
    gate(f"tracing-off overhead <= {tol:.0%}",
         off["tok_s"] >= on["tok_s"] * (1.0 - tol),
         f"off {off['tok_s']:.0f} tok/s vs traced {on['tok_s']:.0f} tok/s")

    slos = [
        ("p99_ms", off["p99_ms"], "<=", env_f("RESMOE_SLO_P99_MS", 10_000.0)),
        ("tok_s", off["tok_s"], ">=", env_f("RESMOE_SLO_TOKS", 1.0)),
        ("hit_rate", off["hit_rate"], ">=", env_f("RESMOE_SLO_HIT_RATE", 0.0)),
        ("prefetch_useful_rate", off["prefetch_useful_rate"], ">=",
         env_f("RESMOE_SLO_PREFETCH_USEFUL", 0.0)),
    ]
    for name, got, op, want in slos:
        ok = got <= want if op == "<=" else got >= want
        gate(f"SLO {name} {op} {want:g}", ok, f"{got:g}")

    lines = [ln for ln in open(trace_path) if ln.strip()]
    want_lines = int(on["requests"])
    gate("one trace line per request", len(lines) == want_lines,
         f"{len(lines)} lines for {want_lines} requests")
    covered_ns = wall_ns = 0
    bad = 0
    stages = set()
    for ln in lines:
        try:
            c, w = validate_line(ln)
        except (AssertionError, KeyError, json.JSONDecodeError) as e:
            bad += 1
            if bad <= 3:
                print(f"  FAIL  malformed trace line: {e}")
            continue
        covered_ns += c
        wall_ns += w
        stages.update(s["stage"] for s in json.loads(ln)["spans"])
    gate("trace lines well-formed", bad == 0, f"{bad} malformed of {len(lines)}")
    attr = covered_ns / wall_ns if wall_ns else 0.0
    attr_min = env_f("RESMOE_OBS_ATTR", 0.95)
    gate(f"stage attribution >= {attr_min:.0%}", attr >= attr_min,
         f"{attr:.1%} of {wall_ns / 1e6:.1f} ms total request wall")

    keys = ("counters", "histograms")
    off_schema = snapshot_schema(off, keys)
    gate("snapshot schema identical across runs",
         off_schema == snapshot_schema(on, keys),
         f"{sum(len(v) for v in off_schema.values())} instruments")

    report = {
        "bench": "obs_gates",
        "kernel": off.get("kernel"),
        "off": {k: off[k] for k in
                ("requests", "req_s", "tok_s", "p50_ms", "p99_ms",
                 "hit_rate", "prefetch_useful_rate")},
        "on": {k: on[k] for k in
               ("requests", "req_s", "tok_s", "p50_ms", "p99_ms",
                "hit_rate", "prefetch_useful_rate")},
        "overhead_frac": 1.0 - off["tok_s"] / on["tok_s"] if on["tok_s"] else None,
        "trace_lines": len(lines),
        "trace_stages": sorted(stages),
        "attributed_frac": attr,
        "gates": {
            "tol": tol, "attr_min": attr_min,
            "slo": {name: want for name, _, _, want in slos},
        },
    }
    gates.write_report("obs", report)
    print(f"  ({len(stages)} distinct stages: {', '.join(sorted(stages))})")
    gates.finish()


if __name__ == "__main__":
    main()
