#!/usr/bin/env python3
"""Seeded validation harness for PR 7 (serving-path observability).

The container has no Rust toolchain, so this script validates the
load-bearing claims of `rust/src/obs/` against faithful Python ports:

1. **Histogram bucket scale is a partition** — the log-linear HDR-style
   scale (16 exact buckets, then 16 sub-buckets per octave up to 2^40)
   must tile u64 latencies with no gaps or overlaps: `bucket_lower` /
   `bucket_upper` are inclusive, adjacent buckets abut exactly, and every
   probed value lands in a bucket whose bounds contain it.

2. **Quantile estimates are conservative and tight** — for seeded sample
   sets, the bucket-upper-bound quantile must never be below the true
   sample quantile and must overshoot by at most one bucket width
   (relative error <= 1/16 for values >= 16, exact below). The mean is
   exact because the histogram tracks the untruncated sum.

3. **Trace emission invariants** — a replica of `emit_request`'s queue
   shift + flat close-order span list must satisfy the same invariants
   `scripts/check_obs.py` and the `prop_obs` suite enforce: spans stay
   within the wall, every depth-d span nests inside a depth-(d-1) parent,
   depth-0 stages sum to at most the wall. A deliberately corrupted
   stream must be rejected.

4. **Prometheus name sanitization** — `resmoe_` prefix plus non-alnum ->
   '_' mapping replicated over the registry's live instrument names.
"""

import json
import random

# ------------------------------------------------ 1. bucket scale replica

HIST_SUB = 16
LINEAR_MAX = 16
MAX_EXP = 39
HIST_BUCKETS = LINEAR_MAX + (MAX_EXP - 3) * HIST_SUB  # 592


def bucket_index(v):
    if v < LINEAR_MAX:
        return v
    v = min(v, (1 << (MAX_EXP + 1)) - 1)
    e = v.bit_length() - 1  # 4..=MAX_EXP
    return LINEAR_MAX + (e - 4) * HIST_SUB + ((v >> (e - 4)) & 15)


def bucket_lower(idx):
    if idx < LINEAR_MAX:
        return idx
    e = 4 + (idx - LINEAR_MAX) // HIST_SUB
    m = (idx - LINEAR_MAX) % HIST_SUB
    return (LINEAR_MAX + m) << (e - 4)


def bucket_upper(idx):
    if idx < LINEAR_MAX:
        return idx
    e = 4 + (idx - LINEAR_MAX) // HIST_SUB
    return bucket_lower(idx) + (1 << (e - 4)) - 1


def check_partition():
    assert HIST_BUCKETS == 592, HIST_BUCKETS
    # Adjacent buckets abut exactly across the whole scale.
    for i in range(HIST_BUCKETS - 1):
        assert bucket_upper(i) + 1 == bucket_lower(i + 1), f"gap at bucket {i}"
    assert bucket_lower(0) == 0
    assert bucket_upper(HIST_BUCKETS - 1) == (1 << (MAX_EXP + 1)) - 1
    # Every probed value lands in a bucket containing it; exhaustive where
    # cheap, boundary +/- 1 probes and seeded random elsewhere.
    rng = random.Random(7)
    probes = list(range(0, 1 << 12))
    for e in range(4, MAX_EXP + 2):
        probes += [(1 << e) - 1, 1 << e, (1 << e) + 1]
    probes += [rng.randrange(1 << 40) for _ in range(20000)]
    probes += [(1 << 40) + rng.randrange(1 << 50) for _ in range(1000)]  # clamp zone
    for v in probes:
        idx = bucket_index(v)
        assert 0 <= idx < HIST_BUCKETS, (v, idx)
        clamped = min(v, (1 << (MAX_EXP + 1)) - 1)
        lo, hi = bucket_lower(idx), bucket_upper(idx)
        assert lo <= clamped <= hi, f"value {v} outside bucket {idx} [{lo}, {hi}]"
        # Relative bucket width bound: the quantile error contract.
        if LINEAR_MAX <= clamped:
            assert (hi - lo) / lo <= 1.0 / HIST_SUB + 1e-12, (v, idx)
    print(f"  bucket scale: {HIST_BUCKETS} buckets tile [0, 2^40) exactly, "
          f"{len(probes)} probes in-bounds, rel width <= 1/{HIST_SUB}")


# ------------------------------------------- 2. quantile + mean contracts

def hist_quantile(buckets, count, q):
    rank = min(max(int(-(-q * count // 1)), 1), count)  # ceil, clamped
    seen = 0
    for idx, c in enumerate(buckets):
        seen += c
        if seen >= rank:
            return bucket_upper(idx)
    return bucket_upper(HIST_BUCKETS - 1)


def check_quantiles():
    rng = random.Random(11)
    for trial, gen in enumerate([
        lambda: rng.randrange(0, 50),                    # exact zone heavy
        lambda: int(rng.expovariate(1 / 50_000)),        # latency-ish ns
        lambda: int(rng.lognormvariate(12, 2)),          # heavy tail
    ]):
        samples = sorted(gen() for _ in range(5000))
        buckets = [0] * HIST_BUCKETS
        total = 0
        for v in samples:
            buckets[bucket_index(v)] += 1
            total += v
        for q in (0.5, 0.9, 0.99, 1.0):
            est = hist_quantile(buckets, len(samples), q)
            rank = min(max(int(-(-q * len(samples) // 1)), 1), len(samples))
            true = min(samples[rank - 1], (1 << (MAX_EXP + 1)) - 1)
            assert est >= true, f"trial {trial} q{q}: est {est} below true {true}"
            if true >= LINEAR_MAX:
                assert est <= true * (1 + 1.0 / HIST_SUB) + 1, \
                    f"trial {trial} q{q}: est {est} vs true {true} too loose"
            else:
                assert est == true, f"trial {trial} q{q}: exact zone mismatch"
        # Mean is exact (untruncated running sum).
        assert total / len(samples) == sum(samples) / len(samples)
    print("  quantiles: conservative and within one bucket width over 3 seeded "
          "distributions; mean exact")


# --------------------------------------------- 3. trace emission replica

def emit_request(req_id, kind, kernel, queue_ns, wall_ns, spans):
    """Replica of trace::emit_request: queue.wait prepended, spans shifted."""
    arr = []
    if queue_ns > 0:
        arr.append({"stage": "queue.wait", "t0": 0, "dur": queue_ns, "depth": 0})
    for s in spans:
        j = {"stage": s["stage"], "t0": s["start"] + queue_ns,
             "dur": max(s["end"] - s["start"], 0), "depth": s["depth"]}
        for k in ("block", "slot"):
            if s.get(k, -1) >= 0:
                j[k] = s[k]
        arr.append(j)
    return json.dumps({"req": req_id, "kind": kind, "kernel": kernel,
                       "queue_ns": queue_ns, "wall_ns": wall_ns, "spans": arr})


def validate_line(line):
    """The invariant set shared with check_obs.py: returns attributed ns."""
    j = json.loads(line)
    wall = j["wall_ns"]
    assert wall > 0 and j["queue_ns"] <= wall
    spans = j["spans"]
    assert spans, "traced request with no spans"
    covered = 0
    for s in spans:
        assert s["t0"] + s["dur"] <= wall, f"span {s['stage']} beyond wall"
        if s["depth"] > 0:
            assert any(p["depth"] == s["depth"] - 1
                       and p["t0"] <= s["t0"]
                       and p["t0"] + p["dur"] >= s["t0"] + s["dur"]
                       for p in spans), f"orphan depth-{s['depth']} span {s['stage']}"
        if s["depth"] == 0:
            covered += s["dur"]
    assert covered <= wall, "depth-0 spans exceed wall"
    return covered


def check_traces():
    # A representative serve: queue wait, forward containing two MoE blocks,
    # each with route/serve/dispatch children, one dispatch with a shard
    # fetch chain, then the head projection. Spans appear in CLOSE order
    # (the Rust guard pushes on drop).
    spans = [
        {"stage": "moe.route", "start": 105, "end": 130, "depth": 2},
        {"stage": "store.read", "start": 160, "end": 300, "depth": 4},
        {"stage": "store.crc", "start": 300, "end": 320, "depth": 4},
        {"stage": "store.decode", "start": 320, "end": 480, "depth": 4},
        {"stage": "cache.shard_fetch", "start": 150, "end": 500, "depth": 3},
        {"stage": "moe.serve", "start": 140, "end": 520, "depth": 2, "block": 2, "slot": 5},
        {"stage": "moe.dispatch", "start": 520, "end": 700, "depth": 2, "block": 2, "slot": 5},
        {"stage": "moe.block", "start": 100, "end": 710, "depth": 1, "block": 2},
        {"stage": "moe.block", "start": 720, "end": 900, "depth": 1, "block": 3},
        {"stage": "forward", "start": 10, "end": 920, "depth": 0},
        {"stage": "head", "start": 925, "end": 990, "depth": 0},
    ]
    line = emit_request(1, "score", "scalar", 400, 1400, spans)
    covered = validate_line(line)
    assert covered == 400 + 910 + 65, covered
    assert covered / 1400 >= 0.95, "representative trace must clear the CI gate"
    # Tags survive emission.
    j = json.loads(line)
    tagged = [s for s in j["spans"] if s["stage"] == "moe.serve"]
    assert tagged and tagged[0]["block"] == 2 and tagged[0]["slot"] == 5
    assert j["spans"][0]["stage"] == "queue.wait" and j["spans"][0]["dur"] == 400
    # Negative cases: the checker must actually reject corrupt streams.
    for mutate in (
        lambda s: s.update(start=1300, end=1500),          # beyond wall
        lambda s: s.update(depth=3),                       # orphan depth
    ):
        bad = [dict(x) for x in spans]
        mutate(bad[0])
        try:
            validate_line(emit_request(2, "score", "scalar", 400, 1400, bad))
        except AssertionError:
            pass
        else:
            raise SystemExit("corrupt trace accepted")
    print("  traces: queue shift + nesting + wall containment verified, "
          "corrupt streams rejected")


# ----------------------------------------- 4. prometheus name sanitation

def prom_name(name):
    return "resmoe_" + "".join(c if c.isalnum() else "_" for c in name)


def check_prom_names():
    cases = {
        "cache.hits": "resmoe_cache_hits",
        "server.latency_us": "resmoe_server_latency_us",
        "batch.occupancy.b3_4": "resmoe_batch_occupancy_b3_4",
        "batch.rows_per_expert.gt8": "resmoe_batch_rows_per_expert_gt8",
    }
    for raw, want in cases.items():
        got = prom_name(raw)
        assert got == want, (raw, got, want)
        assert all(c.isalnum() or c == "_" for c in got)
    print(f"  prometheus names: {len(cases)} registry names sanitize as exported")


def main():
    print("sim_obs: validating observability layer invariants")
    check_partition()
    check_quantiles()
    check_traces()
    check_prom_names()
    print("sim_obs OK")


if __name__ == "__main__":
    main()
