"""Layer-1 Pallas kernels and their pure-jnp oracle (`ref`)."""

from . import barycenter_moe, ref  # noqa: F401
