"""Layer-1 Pallas kernels for the ResMoE inference hot-spot.

Two kernels:

* :func:`grouped_residual_matmul` — the barycenter-shared grouped matmul.
  The shared contribution ``hbase = x @ W1w.T`` is computed once at L2 (XLA
  fuses it); the kernel adds each expert's thin low-rank residual
  correction. Grid = (experts, token tiles).

* :func:`grouped_expert_forward` — fused dense forward of all experts on a
  shared batch (the *uncompressed* comparison path and the dense-routing
  MoE block's inner loop).

TPU mapping (DESIGN.md §Hardware-Adaptation): the BlockSpecs below keep the
barycenter tile resident across the expert grid dimension (index map
ignores `e` for `hbase`/`x`), so on a real TPU the W1w tile stays in VMEM
while only the small U/V factors stream from HBM per expert — the
HBM-traffic analog of "load the barycenter once, residuals on demand".
``interpret=True`` everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls, so correctness runs through the interpreter and real-TPU
performance is estimated analytically (EXPERIMENTS.md §Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _residual_kernel(x_ref, hbase_ref, u_ref, v_ref, o_ref):
    # Block shapes: x [Bt, p], hbase [Bt, pI], u [1, pI, r], v [1, r, p],
    # o [1, Bt, pI]. One grid step = one (expert, token-tile) pair.
    x = x_ref[...]
    u = u_ref[0]
    v = v_ref[0]
    t = jnp.dot(x, v.T)                      # [Bt, r]   — thin
    corr = jnp.dot(t, u.T)                   # [Bt, pI]
    o_ref[0] = hbase_ref[...] + corr


@functools.partial(jax.jit, static_argnames=("block_b",))
def grouped_residual_matmul(x, hbase, u, v, block_b: int = 0):
    """h[e] = hbase + (x @ v[e].T) @ u[e].T for all experts.

    Args:
      x:     [B, p] float32
      hbase: [B, pI] float32 — shared barycenter term (computed once)
      u:     [N, pI, r] float32
      v:     [N, r, p] float32
      block_b: token tile size (0 = whole batch per grid step)
    Returns: [N, B, pI] float32
    """
    b, p = x.shape
    n, pi, r = u.shape
    bt = block_b if block_b and block_b < b else b
    assert b % bt == 0, f"batch {b} not divisible by tile {bt}"
    grid = (n, b // bt)
    return pl.pallas_call(
        _residual_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, p), lambda e, tb: (tb, 0)),        # x: reused across e
            pl.BlockSpec((bt, pi), lambda e, tb: (tb, 0)),       # hbase: reused across e
            pl.BlockSpec((1, pi, r), lambda e, tb: (e, 0, 0)),   # u: streams per expert
            pl.BlockSpec((1, r, p), lambda e, tb: (e, 0, 0)),    # v: streams per expert
        ],
        out_specs=pl.BlockSpec((1, bt, pi), lambda e, tb: (e, tb, 0)),
        out_shape=jax.ShapeDtypeStruct((n, b, pi), x.dtype),
        interpret=True,
    )(x, hbase, u, v)


def _expert_fwd_kernel_relu(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    x = x_ref[...]
    h = jnp.dot(x, w1_ref[0].T) + b1_ref[0][None, :]
    h = jnp.maximum(h, 0.0)
    o_ref[0] = jnp.dot(h, w2_ref[0].T) + b2_ref[0][None, :]


def _expert_fwd_kernel_swiglu(x_ref, w1_ref, b1_ref, w3_ref, b3_ref, w2_ref, b2_ref, o_ref):
    x = x_ref[...]
    h = jnp.dot(x, w1_ref[0].T) + b1_ref[0][None, :]
    g = jnp.dot(x, w3_ref[0].T) + b3_ref[0][None, :]
    h = (h / (1.0 + jnp.exp(-h))) * g
    o_ref[0] = jnp.dot(h, w2_ref[0].T) + b2_ref[0][None, :]


@functools.partial(jax.jit, static_argnames=("block_b",))
def grouped_expert_forward(x, w1, b1, w2, b2, w3=None, b3=None, block_b: int = 0):
    """Dense forward of all experts on a shared batch: [N, B, p].

    Args mirror :func:`ref.grouped_expert_forward_ref`.
    """
    b, p = x.shape
    n, pi, _ = w1.shape
    bt = block_b if block_b and block_b < b else b
    assert b % bt == 0
    grid = (n, b // bt)
    x_spec = pl.BlockSpec((bt, p), lambda e, tb: (tb, 0))
    mat_spec = lambda rows, cols: pl.BlockSpec((1, rows, cols), lambda e, tb: (e, 0, 0))
    vec_spec = lambda cols: pl.BlockSpec((1, cols), lambda e, tb: (e, 0))
    out_spec = pl.BlockSpec((1, bt, p), lambda e, tb: (e, tb, 0))
    out_shape = jax.ShapeDtypeStruct((n, b, p), x.dtype)
    if w3 is None:
        return pl.pallas_call(
            _expert_fwd_kernel_relu,
            grid=grid,
            in_specs=[x_spec, mat_spec(pi, p), vec_spec(pi), mat_spec(p, pi), vec_spec(p)],
            out_specs=out_spec,
            out_shape=out_shape,
            interpret=True,
        )(x, w1, b1, w2, b2)
    return pl.pallas_call(
        _expert_fwd_kernel_swiglu,
        grid=grid,
        in_specs=[
            x_spec,
            mat_spec(pi, p),
            vec_spec(pi),
            mat_spec(pi, p),
            vec_spec(pi),
            mat_spec(p, pi),
            vec_spec(p),
        ],
        out_specs=out_spec,
        out_shape=out_shape,
        interpret=True,
    )(x, w1, b1, w3, b3, w2, b2)


def vmem_bytes_per_step(b, p, pi, r, n_experts=None):
    """Analytic VMEM footprint of one grid step of the residual kernel —
    the §Perf structural metric (interpret-mode wallclock is not a TPU
    proxy).

    Returns (bytes_resident, bytes_streamed_per_expert): the x/hbase tiles
    are resident across the expert dimension; u/v stream per expert.
    """
    resident = 4 * (b * p + b * pi)          # x + hbase tiles
    streamed = 4 * (pi * r + r * p + b * pi) # u + v + output tile
    return resident, streamed


def mxu_utilization_estimate(b, p, pi, r):
    """Fraction of MACs in MXU-shaped (≥8×128-tileable) matmuls for one
    expert's residual correction, vs. the dense-restore alternative.

    The two thin matmuls perform ``b·r·(p+pi)`` MACs vs the dense
    ``b·p·pi``; utilization of the systolic array degrades when r < 8
    (sub-sublane tiles), which this estimate charges as r/8 efficiency.
    """
    thin_macs = b * r * (p + pi)
    dense_macs = b * p * pi
    eff = min(1.0, r / 8.0)
    return {
        "thin_macs": thin_macs,
        "dense_macs": dense_macs,
        "flop_ratio": thin_macs / dense_macs,
        "mxu_efficiency": eff,
        "effective_speedup": dense_macs / (thin_macs / max(eff, 1e-9)),
    }
