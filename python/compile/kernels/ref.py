"""Pure-jnp oracle for the Pallas kernels — the CORE correctness signal.

Everything here is straight-line jnp with no pallas, used by pytest to
validate the kernels under hypothesis-driven shape/value sweeps, and by
`model.py` as the fallback path when kernels are disabled.
"""

import jax.numpy as jnp


def silu(x):
    return x / (1.0 + jnp.exp(-x))


def grouped_residual_matmul_ref(x, hbase, u, v):
    """h[e] = hbase + (x @ v[e].T) @ u[e].T  for every expert e.

    The ResMoE(SVD) inference hot-spot: the barycenter contribution
    ``hbase`` is computed ONCE and shared by every expert; each expert only
    adds a thin rank-r correction.

    Args:
      x:     [B, p]   token activations
      hbase: [B, pI]  shared barycenter contribution  (x @ W1w.T)
      u:     [N, pI, r] residual left factors
      v:     [N, r, p]  residual right factors (singular values folded in)
    Returns: [N, B, pI]
    """
    t = jnp.einsum("bp,nrp->nbr", x, v)
    corr = jnp.einsum("nbr,nir->nbi", t, u)
    return hbase[None, :, :] + corr


def grouped_expert_forward_ref(x, w1, b1, w2, b2, w3=None, b3=None):
    """Dense forward of ALL experts on the same batch.

    Args:
      x:  [B, p]
      w1: [N, pI, p], b1: [N, pI]
      w2: [N, p, pI], b2: [N, p]
      w3/b3: gated path (swiglu) or None (relu)
    Returns: [N, B, p]
    """
    h = jnp.einsum("bp,nip->nbi", x, w1) + b1[:, None, :]
    if w3 is None:
        h = jnp.maximum(h, 0.0)
    else:
        g = jnp.einsum("bp,nip->nbi", x, w3) + b3[:, None, :]
        h = silu(h) * g
    return jnp.einsum("nbi,npi->nbp", h, w2) + b2[:, None, :]


def resmoe_expert_hidden_ref(x, w1_base, b1_base, u1, v1):
    """Hidden pre-activation of restored experts:
    ``h[e] = x @ (W1w + U1[e] V1[e]).T + b1w`` — the factored ResMoE(SVD)
    form of Alg. 2 (restore-then-matmul, algebraically fused).

    Args:
      x: [B, p]; w1_base: [pI, p]; b1_base: [pI]
      u1: [N, pI, r]; v1: [N, r, p]
    Returns: [N, B, pI]
    """
    hbase = x @ w1_base.T + b1_base[None, :]
    return grouped_residual_matmul_ref(x, hbase, u1, v1)
