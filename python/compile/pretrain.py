"""Build-time pretraining (L2): train the mini MoE LMs on the synthetic
corpus with Adam, train frozen-backbone classification heads for the NLU
tasks, and save RMW1 checkpoints the rust runtime loads.

Runs ONCE under `make artifacts`; never on the request path. The corpus and
task datasets are produced by `resmoe datagen` (rust is the single source
of truth for data) — this script only consumes them.

Usage: python -m compile.pretrain --out ../artifacts [--steps N] [--fast]
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import checkpoint
from .common import ALL_CONFIGS, ModelConfig
from .model import batched_logits, hidden_states, init_params


def load_corpus(data_dir):
    with open(os.path.join(data_dir, "corpus.json")) as f:
        c = json.load(f)
    return np.array(c["train"], np.int32), np.array(c["valid"], np.int32)


def load_task(data_dir, task):
    with open(os.path.join(data_dir, f"{task}.json")) as f:
        d = json.load(f)
    return d


def sample_windows(stream, batch, seq, rng):
    starts = rng.integers(0, len(stream) - seq - 1, size=batch)
    return np.stack([stream[s : s + seq] for s in starts])


def ce_loss(params, cfg, tokens):
    """Mean next-token cross-entropy over a [B, T] batch."""
    logits = batched_logits(params, cfg, tokens)  # [B, T, V]
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits[:, :-1, :], axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return jnp.mean(nll)


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": 0}


def adam_step(params, grads, state, lr, b1=0.9, b2=0.98, eps=1e-8, wd=0.01):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1**t)
    vhat_scale = 1.0 / (1 - b2**t)
    new_params = jax.tree_util.tree_map(
        lambda p, m_, v_: p
        - lr * (m_ * mhat_scale / (jnp.sqrt(v_ * vhat_scale) + eps) + wd * p),
        params,
        m,
        v,
    )
    return new_params, {"m": m, "v": v, "t": t}


def train_lm(cfg: ModelConfig, train_stream, valid_stream, steps, batch, seq, lr, log):
    key = jax.random.key(hash(cfg.name) % (2**31))
    params = init_params(cfg, key)
    state = adam_init(params)
    rng = np.random.default_rng(0xC0DE)
    warmup = 8  # paper Table 6

    @jax.jit
    def step_fn(params, state, tokens, lr_t):
        loss, grads = jax.value_and_grad(ce_loss)(params, cfg, tokens)
        params, state = adam_step(params, grads, state, lr_t)
        return params, state, loss

    t0 = time.time()
    for step in range(steps):
        lr_t = lr * min(1.0, (step + 1) / warmup)
        tokens = jnp.array(sample_windows(train_stream, batch, seq, rng))
        params, state, loss = step_fn(params, state, tokens, lr_t)
        if step % 10 == 0 or step == steps - 1:
            log["loss_curve"].append({"step": step, "loss": float(loss)})
            print(
                f"  [{cfg.name}] step {step:4d} loss {float(loss):.4f} "
                f"({time.time()-t0:.0f}s)",
                flush=True,
            )
    # Validation PPL.
    vwin = (len(valid_stream) // seq) * seq
    vtok = jnp.array(valid_stream[:vwin].reshape(-1, seq))
    vloss = float(
        np.mean([float(ce_loss(params, cfg, vtok[i : i + batch])) for i in range(0, len(vtok), batch)])
    )
    log["valid_ppl"] = float(np.exp(vloss))
    print(f"  [{cfg.name}] valid ppl {log['valid_ppl']:.3f}", flush=True)
    return params


def features_for_examples(params, cfg, examples, batch=32):
    """Final-position hidden states for classification examples.
    Right-padding is safe under causal attention: the hidden state at the
    last REAL position never attends to padding."""
    max_len = min(cfg.max_seq, max(len(e["tokens"]) for e in examples))
    feats = []
    labels = []

    @jax.jit
    def hs(tokens):
        return jax.vmap(lambda t: hidden_states(params, cfg, t))(tokens)

    for i in range(0, len(examples), batch):
        chunk = examples[i : i + batch]
        toks = np.zeros((len(chunk), max_len), np.int32)
        idx = np.zeros(len(chunk), np.int32)
        for j, e in enumerate(chunk):
            t = e["tokens"][-max_len:]
            toks[j, : len(t)] = t
            idx[j] = len(t) - 1
        h = np.asarray(hs(jnp.array(toks)))
        feats.append(h[np.arange(len(chunk)), idx])
        labels.extend(e["label"] for e in chunk)
    return np.concatenate(feats), np.array(labels, np.int32)


def train_head(feats, labels, n_classes, steps=400, lr=0.05):
    """Multinomial logistic regression head (experts/backbone frozen, per
    the paper's fine-tuning protocol §5.1)."""
    d = feats.shape[1]
    w = jnp.zeros((n_classes, d), jnp.float32)
    x = jnp.array(feats)
    y = jnp.array(labels)

    @jax.jit
    def loss_fn(w):
        logits = x @ w.T
        logp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))

    grad_fn = jax.jit(jax.grad(loss_fn))
    m = jnp.zeros_like(w)
    for _ in range(steps):
        g = grad_fn(w)
        m = 0.9 * m + g
        w = w - lr * m
    acc = float(jnp.mean((x @ w.T).argmax(-1) == y))
    return np.asarray(w), acc


# Which heads to train per model (paper: NLU on Switch; MRPC-only for the
# 16-expert scale test, Table 5).
HEAD_TASKS = {
    "switch-mini-8": ["sst2", "mrpc", "cola", "mnli"],
    "switch-mini-16": ["mrpc"],
    "mixtral-mini": [],
    "deepseek-mini": [],
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=int(os.environ.get("RESMOE_STEPS", 220)))
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--fast", action="store_true", help="smoke-test sizes")
    ap.add_argument("--models", default="switch-mini-8,switch-mini-16,mixtral-mini,deepseek-mini")
    args = ap.parse_args()
    data_dir = os.path.join(args.out, "data")
    if not os.path.exists(os.path.join(data_dir, "corpus.json")):
        raise SystemExit(
            f"{data_dir}/corpus.json missing — run `cargo run --release -- datagen` "
            "(the Makefile `artifacts` target does this)"
        )
    train_stream, valid_stream = load_corpus(data_dir)
    steps = 20 if args.fast else args.steps
    full_log = {}
    for name in args.models.split(","):
        cfg = ALL_CONFIGS[name]
        print(f"== pretraining {name} ({steps} steps) ==", flush=True)
        log = {"model": name, "steps": steps, "loss_curve": []}
        params = train_lm(cfg, train_stream, valid_stream, steps, args.batch, args.seq, args.lr, log)
        tensors = {k: np.asarray(v) for k, v in params.items()}
        # Heads on frozen features.
        log["heads"] = {}
        for task in HEAD_TASKS[name]:
            d = load_task(data_dir, task)
            tr = d["train"][: 400 if args.fast else len(d["train"])]
            feats, labels = features_for_examples(params, cfg, tr)
            w, acc = train_head(feats, labels, d["n_classes"])
            tensors[f"head.{task}"] = w
            log["heads"][task] = {"train_acc": acc}
            print(f"  [{name}] head {task}: train acc {acc:.3f}", flush=True)
        path = os.path.join(args.out, f"{name}.rmw")
        checkpoint.save_checkpoint(path, cfg.to_json_dict(), tensors)
        print(f"  wrote {path}", flush=True)
        full_log[name] = log
    with open(os.path.join(args.out, "pretrain_log.json"), "w") as f:
        json.dump(full_log, f, indent=1)


if __name__ == "__main__":
    main()
