"""Layer-2 JAX model: the decoder-only MoE LM, mirroring
`rust/src/moe/transformer.rs` op-for-op (RMSNorm eps 1e-6, learned
positional embeddings, pre-norm blocks, `x @ W.T` linear convention) so that
checkpoints trained here evaluate identically in rust.

Also defines the two MoE-block formulations that get AOT-lowered:

* :func:`moe_block_dense` — dense routing over the original experts, inner
  compute through the Pallas :func:`grouped_expert_forward` kernel.
* :func:`moe_block_resmoe` — the ResMoE(SVD) factored form: one shared
  barycenter expert plus per-expert low-rank residual corrections through
  the Pallas :func:`grouped_residual_matmul` kernel (Alg. 2 fused).
"""

import jax
import jax.numpy as jnp

from .common import ModelConfig
from .kernels import barycenter_moe as kernels
from .kernels import ref


def rmsnorm(x, gain):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + 1e-6) * gain


def router_probs(x, w_g, top_k):
    """Dense top-k routing weights: [B, N] with exactly top_k nonzeros/row,
    softmax-normalized over the selected logits (paper §3.1).

    Implemented with `sort` rather than `lax.top_k`: jax lowers top_k to the
    HLO `topk` instruction, which the xla_extension-0.5.1 text parser (the
    rust runtime's loader) does not know. `sort` round-trips fine.
    """
    logits = x @ w_g.T                                   # [B, N]
    sorted_logits = jnp.sort(logits, axis=-1)            # ascending
    thresh = sorted_logits[:, -top_k][:, None]
    neg = jnp.finfo(logits.dtype).min
    masked = jnp.where(logits >= thresh, logits, neg)
    return jax.nn.softmax(masked, axis=-1)


def moe_block_dense(x, w_g, w1, b1, w2, b2, w3=None, b3=None, top_k=2, use_kernel=True):
    """Dense-dispatch MoE layer: every expert computes the whole batch, the
    router weights combine. Clean HLO for AOT; N× FLOPs is fine at mini
    scale.

    x [B,p]; w_g [N,p]; w1 [N,pI,p]; b1 [N,pI]; w2 [N,p,pI]; b2 [N,p].
    Returns [B, p].
    """
    probs = router_probs(x, w_g, top_k)                  # [B, N]
    fwd = kernels.grouped_expert_forward if use_kernel else ref.grouped_expert_forward_ref
    y = fwd(x, w1, b1, w2, b2, w3, b3)                   # [N, B, p]
    return jnp.einsum("bn,nbp->bp", probs, y)


def moe_block_resmoe(
    x,
    w_g,
    base_w1,
    base_b1,
    u1,
    v1,
    base_w2,
    u2,
    v2,
    b2,
    base_w3=None,
    base_b3=None,
    u3=None,
    v3=None,
    top_k=2,
    use_kernel=True,
):
    """ResMoE(SVD)-compressed MoE layer in factored form.

    Restored weights are ``W1_k = W1w + U1[k] V1[k]`` etc. The shared
    barycenter matmuls are computed ONCE per batch; per-expert corrections
    are rank-r. This is Algorithm 2 with the restore fused into the matmul.

    Shapes: base_w1 [pI,p], u1 [N,pI,r], v1 [N,r,p]; base_w2 [p,pI],
    u2 [N,p,r2], v2 [N,r2,pI]; b2 [N,p].
    """
    n = u1.shape[0]
    probs = router_probs(x, w_g, top_k)                  # [B, N]
    grm = kernels.grouped_residual_matmul if use_kernel else ref.grouped_residual_matmul_ref
    # --- hidden pre-activation: shared base + per-expert correction.
    hbase1 = x @ base_w1.T + base_b1[None, :]            # [B, pI] (once!)
    h = grm(x, hbase1, u1, v1)                           # [N, B, pI]
    if base_w3 is None:
        h = jnp.maximum(h, 0.0)
    else:
        hbase3 = x @ base_w3.T + base_b3[None, :]
        g = grm(x, hbase3, u3, v3)
        h = (h / (1.0 + jnp.exp(-h))) * g
    # --- output projection: shared base W2w on the MEAN activation cannot
    # be shared exactly (h differs per expert), so the base matmul runs per
    # expert but the residual stays rank-r2: y[k] = h[k] @ (W2w + U2 V2).T.
    y_base = jnp.einsum("nbi,pi->nbp", h, base_w2)       # [N, B, p]
    t = jnp.einsum("nbi,nri->nbr", h, v2)                # [N, B, r2]
    y_corr = jnp.einsum("nbr,npr->nbp", t, u2)
    y = y_base + y_corr + b2[:, None, :]
    _ = n
    return jnp.einsum("bn,nbp->bp", probs, y)


# --------------------------------------------------------------- full model

def init_params(cfg: ModelConfig, key):
    """Initialize full-model parameters as a pytree of jnp arrays keyed by
    the RMW1 tensor names (flat dict)."""
    import numpy as np

    rng = np.random.default_rng(np.asarray(jax.random.key_data(key)).sum() % (2**63))
    d, pi, v = cfg.d_model, cfg.d_inner, cfg.vocab_size
    s_emb = 0.02
    p = {}

    def mat(shape, std):
        return jnp.array(rng.normal(0.0, std, size=shape), jnp.float32)

    p["embed"] = mat((v, d), s_emb)
    p["pos"] = mat((cfg.max_seq, d), s_emb)
    p["lm_head"] = mat((v, d), s_emb)
    p["final_norm"] = jnp.ones((d,), jnp.float32)
    s1 = 1.0 / d**0.5
    s2 = 1.0 / pi**0.5
    for i in range(cfg.n_layers):
        pre = f"blocks.{i}"
        p[f"{pre}.norm1"] = jnp.ones((d,), jnp.float32)
        p[f"{pre}.norm2"] = jnp.ones((d,), jnp.float32)
        for w in ("wq", "wk", "wv", "wo"):
            p[f"{pre}.attn.{w}"] = mat((d, d), s1)
        def expert(prefix, base=None, noise=0.0):
            if base is None:
                p[f"{prefix}.w1"] = mat((pi, d), s1)
                p[f"{prefix}.b1"] = jnp.zeros((pi,), jnp.float32)
                if cfg.arch == "swiglu":
                    p[f"{prefix}.w3"] = mat((pi, d), s1)
                    p[f"{prefix}.b3"] = jnp.zeros((pi,), jnp.float32)
                p[f"{prefix}.w2"] = mat((d, pi), s2)
                p[f"{prefix}.b2"] = jnp.zeros((d,), jnp.float32)
            else:
                for name in base:
                    p[f"{prefix}.{name}"] = base[name] + mat(base[name].shape, noise)
        if cfg.is_moe_layer(i):
            p[f"{pre}.ffn.router.w_g"] = mat((cfg.n_experts, d), s1)
            if cfg.expert_init == "upcycled":
                base = {}
                base["w1"] = mat((pi, d), s1)
                base["b1"] = jnp.zeros((pi,), jnp.float32)
                if cfg.arch == "swiglu":
                    base["w3"] = mat((pi, d), s1)
                    base["b3"] = jnp.zeros((pi,), jnp.float32)
                base["w2"] = mat((d, pi), s2)
                base["b2"] = jnp.zeros((d,), jnp.float32)
                for k in range(cfg.n_experts):
                    expert(f"{pre}.ffn.experts.{k}", base=base, noise=0.02)
            else:
                for k in range(cfg.n_experts):
                    expert(f"{pre}.ffn.experts.{k}")
            if cfg.shared_expert:
                expert(f"{pre}.ffn.shared")
        else:
            expert(f"{pre}.ffn.dense")
    return p


def _expert_stack(params, prefix, n, names):
    return {
        name: jnp.stack([params[f"{prefix}.experts.{k}.{name}"] for k in range(n)])
        for name in names
    }


def _expert_apply(params, prefix, cfg, x):
    """Dense FFN expert at `prefix` applied to [T, d]."""
    h = x @ params[f"{prefix}.w1"].T + params[f"{prefix}.b1"][None, :]
    if cfg.arch == "swiglu":
        g = x @ params[f"{prefix}.w3"].T + params[f"{prefix}.b3"][None, :]
        h = (h / (1.0 + jnp.exp(-h))) * g
    else:
        h = jnp.maximum(h, 0.0)
    return h @ params[f"{prefix}.w2"].T + params[f"{prefix}.b2"][None, :]


def _moe_apply(params, prefix, cfg, x):
    names = ["w1", "b1", "w2", "b2"] + (["w3", "b3"] if cfg.arch == "swiglu" else [])
    st = _expert_stack(params, prefix, cfg.n_experts, names)
    y = moe_block_dense(
        x,
        params[f"{prefix}.router.w_g"],
        st["w1"],
        st["b1"],
        st["w2"],
        st["b2"],
        st.get("w3"),
        st.get("b3"),
        top_k=cfg.top_k,
        use_kernel=False,  # training path: plain jnp (fast to trace/grad)
    )
    if cfg.shared_expert:
        y = y + _expert_apply(params, f"{prefix}.shared", cfg, x)
    return y


def hidden_states(params, cfg: ModelConfig, tokens):
    """tokens [T] int32 → final-norm hidden states [T, d]."""
    t = tokens.shape[0]
    h = params["embed"][tokens] + params["pos"][:t]
    hd = cfg.head_dim()
    scale = 1.0 / hd**0.5
    mask = jnp.tril(jnp.ones((t, t), bool))
    for i in range(cfg.n_layers):
        pre = f"blocks.{i}"
        hn = rmsnorm(h, params[f"{pre}.norm1"])
        q = hn @ params[f"{pre}.attn.wq"].T
        k = hn @ params[f"{pre}.attn.wk"].T
        v = hn @ params[f"{pre}.attn.wv"].T
        q = q.reshape(t, cfg.n_heads, hd).transpose(1, 0, 2)
        k = k.reshape(t, cfg.n_heads, hd).transpose(1, 0, 2)
        v = v.reshape(t, cfg.n_heads, hd).transpose(1, 0, 2)
        scores = jnp.einsum("htd,hsd->hts", q, k) * scale
        scores = jnp.where(mask[None, :, :], scores, jnp.finfo(scores.dtype).min)
        probs = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("hts,hsd->htd", probs, v).transpose(1, 0, 2).reshape(t, cfg.d_model)
        h = h + ctx @ params[f"{pre}.attn.wo"].T
        hn = rmsnorm(h, params[f"{pre}.norm2"])
        if cfg.is_moe_layer(i):
            ffn = _moe_apply(params, f"{pre}.ffn", cfg, hn)
        else:
            ffn = _expert_apply(params, f"{pre}.ffn.dense", cfg, hn)
        h = h + ffn
    return rmsnorm(h, params["final_norm"])


def logits_fn(params, cfg: ModelConfig, tokens):
    """tokens [T] → next-token logits [T, vocab]."""
    return hidden_states(params, cfg, tokens) @ params["lm_head"].T


def batched_logits(params, cfg: ModelConfig, token_batch):
    """[B, T] → [B, T, vocab] (the AOT scoring artifact's body)."""
    return jax.vmap(lambda t: logits_fn(params, cfg, t))(token_batch)
