"""Shared model configurations, mirroring `rust/src/moe/config.rs` presets.

Every linear layer stores weights as ``[out, in]`` and applies ``x @ W.T``
(the rust convention), so checkpoints round-trip bit-exactly.
"""

from dataclasses import dataclass, asdict


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab_size: int
    d_model: int      # p
    d_inner: int      # p_I
    n_layers: int
    n_heads: int
    max_seq: int
    n_experts: int    # N
    top_k: int
    arch: str         # "relu" | "swiglu"
    expert_init: str  # "independent" | "upcycled"
    moe_every: int
    shared_expert: bool

    def is_moe_layer(self, layer: int) -> bool:
        return (layer + 1) % self.moe_every == 0

    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def to_json_dict(self) -> dict:
        return asdict(self)


def switch_mini(n_experts: int = 8) -> ModelConfig:
    return ModelConfig(
        name=f"switch-mini-{n_experts}",
        vocab_size=256,
        d_model=64,
        d_inner=256,
        n_layers=6,
        n_heads=4,
        max_seq=128,
        n_experts=n_experts,
        top_k=1,
        arch="relu",
        expert_init="independent",
        moe_every=2,
        shared_expert=False,
    )


def mixtral_mini() -> ModelConfig:
    return ModelConfig(
        name="mixtral-mini",
        vocab_size=256,
        d_model=64,
        d_inner=224,
        n_layers=6,
        n_heads=4,
        max_seq=128,
        n_experts=8,
        top_k=2,
        arch="swiglu",
        expert_init="upcycled",
        moe_every=1,
        shared_expert=False,
    )


def deepseek_mini() -> ModelConfig:
    return ModelConfig(
        name="deepseek-mini",
        vocab_size=256,
        d_model=64,
        d_inner=44,
        n_layers=4,
        n_heads=4,
        max_seq=128,
        n_experts=64,
        top_k=6,
        arch="swiglu",
        expert_init="upcycled",
        moe_every=1,
        shared_expert=True,
    )


ALL_CONFIGS = {
    "switch-mini-8": switch_mini(8),
    "switch-mini-16": switch_mini(16),
    "mixtral-mini": mixtral_mini(),
    "deepseek-mini": deepseek_mini(),
}
