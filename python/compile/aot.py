"""AOT lowering (build path): JAX/Pallas → HLO **text** artifacts the rust
runtime loads via the PJRT C API.

HLO text — not `.serialize()` — is the interchange format: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (manifest.json indexes them):
* `lm_score_<model>_b<B>.hlo.txt` — batched scoring: tokens i32 [B, T] plus
  the model weights (arguments, in manifest order) → logits f32 [B, T, V].
* `moe_block_dense_<arch>.hlo.txt` — one MoE layer, dense routing, inner
  compute through the Pallas grouped_expert_forward kernel.
* `moe_block_resmoe_<arch>.hlo.txt` — the ResMoE(SVD) factored layer
  through the Pallas grouped_residual_matmul kernel (Alg. 2, fused).

Usage: python -m compile.aot --out ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import checkpoint
from .common import ALL_CONFIGS
from .model import batched_logits, moe_block_dense, moe_block_resmoe


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec_of(x):
    return {"shape": list(x.shape), "dtype": str(x.dtype)}


def lower_lm_score(cfg, params, batch_sizes, out_dir, manifest):
    names = sorted(k for k in params.keys() if not k.startswith("head."))
    weights = [jnp.asarray(params[n], jnp.float32) for n in names]

    def fn(tokens, *ws):
        p = dict(zip(names, ws))
        return (batched_logits(p, cfg, tokens),)

    for b in batch_sizes:
        tok_spec = jax.ShapeDtypeStruct((b, cfg.max_seq), jnp.int32)
        w_specs = [jax.ShapeDtypeStruct(w.shape, w.dtype) for w in weights]
        lowered = jax.jit(fn).lower(tok_spec, *w_specs)
        fname = f"lm_score_{cfg.name}_b{b}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(to_hlo_text(lowered))
        manifest["artifacts"].append(
            {
                "name": f"lm_score_{cfg.name}_b{b}",
                "kind": "lm_score",
                "model": cfg.name,
                "file": fname,
                "batch": b,
                "seq": cfg.max_seq,
                "vocab": cfg.vocab_size,
                "inputs": [{"name": "tokens", "shape": [b, cfg.max_seq], "dtype": "int32"}]
                + [{"name": n, **spec_of(w)} for n, w in zip(names, weights)],
                "output": {"shape": [b, cfg.max_seq, cfg.vocab_size], "dtype": "float32"},
            }
        )
        print(f"  wrote {fname}", flush=True)


def lower_moe_blocks(out_dir, manifest):
    """Fixed mini-geometry MoE blocks (the kernel-path artifacts used by
    the rust↔python agreement tests and the serving hot path)."""
    b, p, pi, n, top_k, r = 16, 64, 224, 8, 2, 24
    f32 = jnp.float32

    # ---- dense block (Pallas grouped_expert_forward inside).
    def dense_fn(x, w_g, w1, b1, w3, b3, w2, b2):
        return (
            moe_block_dense(x, w_g, w1, b1, w2, b2, w3, b3, top_k=top_k, use_kernel=True),
        )

    specs = [
        jax.ShapeDtypeStruct(s, f32)
        for s in [
            (b, p),
            (n, p),
            (n, pi, p),
            (n, pi),
            (n, pi, p),
            (n, pi),
            (n, p, pi),
            (n, p),
        ]
    ]
    lowered = jax.jit(dense_fn).lower(*specs)
    fname = "moe_block_dense_swiglu.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(to_hlo_text(lowered))
    input_names = ["x", "w_g", "w1", "b1", "w3", "b3", "w2", "b2"]
    manifest["artifacts"].append(
        {
            "name": "moe_block_dense_swiglu",
            "kind": "moe_block",
            "file": fname,
            "geometry": {"b": b, "p": p, "pi": pi, "n": n, "top_k": top_k},
            "inputs": [
                {"name": nm, "shape": list(s.shape), "dtype": "float32"}
                for nm, s in zip(input_names, specs)
            ],
            "output": {"shape": [b, p], "dtype": "float32"},
        }
    )
    print(f"  wrote {fname}", flush=True)

    # ---- resmoe factored block (Pallas grouped_residual_matmul inside).
    def resmoe_fn(x, w_g, bw1, bb1, u1, v1, bw3, bb3, u3, v3, bw2, u2, v2, b2):
        return (
            moe_block_resmoe(
                x, w_g, bw1, bb1, u1, v1, bw2, u2, v2, b2,
                base_w3=bw3, base_b3=bb3, u3=u3, v3=v3,
                top_k=top_k, use_kernel=True,
            ),
        )

    r2 = r
    shapes = [
        (b, p), (n, p),
        (pi, p), (pi,), (n, pi, r), (n, r, p),
        (pi, p), (pi,), (n, pi, r), (n, r, p),
        (p, pi), (n, p, r2), (n, r2, pi), (n, p),
    ]
    rspecs = [jax.ShapeDtypeStruct(s, f32) for s in shapes]
    lowered = jax.jit(resmoe_fn).lower(*rspecs)
    fname = "moe_block_resmoe_swiglu.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(to_hlo_text(lowered))
    rnames = [
        "x", "w_g", "base_w1", "base_b1", "u1", "v1",
        "base_w3", "base_b3", "u3", "v3", "base_w2", "u2", "v2", "b2",
    ]
    manifest["artifacts"].append(
        {
            "name": "moe_block_resmoe_swiglu",
            "kind": "moe_block_resmoe",
            "file": fname,
            "geometry": {"b": b, "p": p, "pi": pi, "n": n, "top_k": top_k, "rank": r},
            "inputs": [
                {"name": nm, "shape": list(s.shape), "dtype": "float32"}
                for nm, s in zip(rnames, rspecs)
            ],
            "output": {"shape": [b, p], "dtype": "float32"},
        }
    )
    print(f"  wrote {fname}", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--batches", default="1,4,16")
    ap.add_argument("--models", default="mixtral-mini,switch-mini-8")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    manifest = {"version": 1, "artifacts": []}
    batch_sizes = [int(x) for x in args.batches.split(",")]
    for name in args.models.split(","):
        cfg = ALL_CONFIGS[name]
        ckpt_path = os.path.join(args.out, f"{name}.rmw")
        if not os.path.exists(ckpt_path):
            print(f"  skipping {name}: checkpoint missing (run pretrain first)")
            continue
        _, tensors = checkpoint.load_checkpoint(ckpt_path)
        params = {k: np.asarray(v) for k, v in tensors.items()}
        # Restore vector shapes (1×n → n) for model application.
        for k in list(params.keys()):
            if params[k].shape[0] == 1 and (
                k.endswith((".b1", ".b2", ".b3", "norm1", "norm2")) or k == "final_norm"
            ):
                params[k] = params[k][0]
        print(f"== lowering lm_score for {name} ==", flush=True)
        lower_lm_score(cfg, params, batch_sizes, args.out, manifest)
    print("== lowering MoE block kernels ==", flush=True)
    lower_moe_blocks(args.out, manifest)
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"  wrote manifest.json ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
