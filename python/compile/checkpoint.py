"""RMW1 checkpoint writer/reader — the python half of
`rust/src/moe/model_io.rs`.

Layout: ``b"RMW1" | u32 header_len | JSON header | f32-LE blob``.
Tensor names follow the rust module paths (``blocks.3.ffn.experts.5.w1``).
Vectors are 1×n tensors; all matrices are ``[out, in]``.
"""

import json
import struct

import numpy as np

MAGIC = b"RMW1"


def save_checkpoint(path: str, config_dict: dict, tensors: dict) -> None:
    """tensors: name -> 1-D or 2-D float32 numpy array."""
    directory = []
    blobs = []
    offset = 0
    for name, arr in tensors.items():
        a = np.asarray(arr, dtype=np.float32)
        if a.ndim == 1:
            a = a.reshape(1, -1)
        if a.ndim != 2:
            raise ValueError(f"tensor {name} has ndim {a.ndim}")
        directory.append(
            {"name": name, "rows": int(a.shape[0]), "cols": int(a.shape[1]), "offset": offset}
        )
        offset += a.size
        blobs.append(np.ascontiguousarray(a).tobytes())
    header = json.dumps(
        {"config": config_dict, "tensors": directory}, sort_keys=True, separators=(",", ":")
    ).encode()
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(header)))
        f.write(header)
        for b in blobs:
            f.write(b)


def load_checkpoint(path: str):
    """Returns (config_dict, {name: np.ndarray[rows, cols]})."""
    with open(path, "rb") as f:
        magic = f.read(4)
        if magic != MAGIC:
            raise ValueError(f"{path}: bad magic {magic!r}")
        (header_len,) = struct.unpack("<I", f.read(4))
        header = json.loads(f.read(header_len))
        blob = np.frombuffer(f.read(), dtype="<f4")
    tensors = {}
    for t in header["tensors"]:
        n = t["rows"] * t["cols"]
        tensors[t["name"]] = blob[t["offset"] : t["offset"] + n].reshape(
            t["rows"], t["cols"]
        ).copy()
    return header["config"], tensors
