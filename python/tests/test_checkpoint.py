"""RMW1 checkpoint format tests (python side of the rust↔python contract)."""

import os
import struct
import tempfile

import numpy as np
import pytest

from compile import checkpoint
from compile.common import switch_mini


def test_roundtrip():
    cfg = switch_mini(4)
    tensors = {
        "embed": np.random.default_rng(0).normal(size=(8, 4)).astype(np.float32),
        "final_norm": np.ones(4, np.float32),  # 1-D becomes 1×4
    }
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "x.rmw")
        checkpoint.save_checkpoint(path, cfg.to_json_dict(), tensors)
        cfg2, t2 = checkpoint.load_checkpoint(path)
        assert cfg2["name"] == cfg.name
        assert t2["embed"].shape == (8, 4)
        np.testing.assert_array_equal(t2["embed"], tensors["embed"])
        assert t2["final_norm"].shape == (1, 4)


def test_header_is_json_with_magic():
    cfg = switch_mini(4)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "x.rmw")
        checkpoint.save_checkpoint(path, cfg.to_json_dict(), {"a": np.zeros((2, 2), np.float32)})
        with open(path, "rb") as f:
            assert f.read(4) == b"RMW1"
            (n,) = struct.unpack("<I", f.read(4))
            header = f.read(n)
            import json

            h = json.loads(header)
            assert h["tensors"][0]["name"] == "a"
            assert h["config"]["arch"] == "relu"


def test_rejects_bad_magic():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "bad.rmw")
        with open(path, "wb") as f:
            f.write(b"NOPE1234")
        with pytest.raises(ValueError):
            checkpoint.load_checkpoint(path)


def test_rejects_3d_tensor():
    cfg = switch_mini(4)
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(ValueError):
            checkpoint.save_checkpoint(
                os.path.join(d, "x.rmw"),
                cfg.to_json_dict(),
                {"bad": np.zeros((2, 2, 2), np.float32)},
            )
