"""L2 model tests: routing semantics, dense-vs-kernel block agreement,
ResMoE factored-block equivalence with explicit restoration, and full-model
shape checks."""

import jax
import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from compile import model
from compile.common import ModelConfig


def tiny_cfg(**kw):
    base = dict(
        name="tiny",
        vocab_size=32,
        d_model=16,
        d_inner=24,
        n_layers=2,
        n_heads=2,
        max_seq=16,
        n_experts=4,
        top_k=2,
        arch="relu",
        expert_init="independent",
        moe_every=2,
        shared_expert=False,
    )
    base.update(kw)
    return ModelConfig(**base)


def rand(rng, *shape):
    return jnp.array(rng.normal(size=shape), jnp.float32)


def test_router_probs_topk_support():
    rng = np.random.default_rng(0)
    x = rand(rng, 10, 8)
    w_g = rand(rng, 6, 8)
    probs = model.router_probs(x, w_g, 2)
    nz = np.asarray(probs > 1e-9).sum(axis=1)
    assert (nz == 2).all()
    assert_allclose(np.asarray(probs.sum(axis=1)), np.ones(10), rtol=1e-5)


def test_moe_block_kernel_matches_jnp_path():
    rng = np.random.default_rng(1)
    b, p, pi, n = 8, 16, 24, 4
    x = rand(rng, b, p)
    w_g = rand(rng, n, p)
    w1 = rand(rng, n, pi, p)
    b1 = rand(rng, n, pi)
    w2 = rand(rng, n, p, pi)
    b2 = rand(rng, n, p)
    w3 = rand(rng, n, pi, p)
    b3 = rand(rng, n, pi)
    y_kernel = model.moe_block_dense(x, w_g, w1, b1, w2, b2, w3, b3, top_k=2, use_kernel=True)
    y_jnp = model.moe_block_dense(x, w_g, w1, b1, w2, b2, w3, b3, top_k=2, use_kernel=False)
    assert_allclose(np.asarray(y_kernel), np.asarray(y_jnp), rtol=1e-4, atol=1e-4)


def test_resmoe_block_equals_restored_dense():
    """moe_block_resmoe(factored) == moe_block_dense(restored weights) —
    the L2 statement of Algorithm 2."""
    rng = np.random.default_rng(2)
    b, p, pi, n, r = 8, 12, 18, 3, 4
    x = rand(rng, b, p)
    w_g = rand(rng, n, p)
    bw1, bb1 = rand(rng, pi, p), rand(rng, pi)
    bw3, bb3 = rand(rng, pi, p), rand(rng, pi)
    bw2 = rand(rng, p, pi)
    u1, v1 = rand(rng, n, pi, r), rand(rng, n, r, p)
    u3, v3 = rand(rng, n, pi, r), rand(rng, n, r, p)
    u2, v2 = rand(rng, n, p, r), rand(rng, n, r, pi)
    b2 = rand(rng, n, p)
    y_fact = model.moe_block_resmoe(
        x, w_g, bw1, bb1, u1, v1, bw2, u2, v2, b2,
        base_w3=bw3, base_b3=bb3, u3=u3, v3=v3, top_k=2, use_kernel=True,
    )
    # Restore dense weights per expert.
    w1 = jnp.stack([bw1 + u1[e] @ v1[e] for e in range(n)])
    w3 = jnp.stack([bw3 + u3[e] @ v3[e] for e in range(n)])
    w2 = jnp.stack([bw2 + u2[e] @ v2[e] for e in range(n)])
    b1s = jnp.stack([bb1] * n)
    b3s = jnp.stack([bb3] * n)
    y_dense = model.moe_block_dense(x, w_g, w1, b1s, w2, b2, w3, b3s, top_k=2, use_kernel=False)
    assert_allclose(np.asarray(y_fact), np.asarray(y_dense), rtol=2e-3, atol=2e-3)


def test_full_model_shapes_and_determinism():
    cfg = tiny_cfg()
    params = model.init_params(cfg, jax.random.key(0))
    tokens = jnp.array(np.arange(10) % 32, jnp.int32)
    logits = model.logits_fn(params, cfg, tokens)
    assert logits.shape == (10, 32)
    logits2 = model.logits_fn(params, cfg, tokens)
    assert_allclose(np.asarray(logits), np.asarray(logits2))


def test_upcycled_init_is_tighter_than_independent():
    cfg_up = tiny_cfg(expert_init="upcycled", arch="swiglu")
    cfg_ind = tiny_cfg(expert_init="independent", arch="swiglu")
    p_up = model.init_params(cfg_up, jax.random.key(1))
    p_ind = model.init_params(cfg_ind, jax.random.key(1))

    def spread(p):
        ws = [np.asarray(p[f"blocks.1.ffn.experts.{k}.w1"]) for k in range(4)]
        mean = np.mean(ws, axis=0)
        return float(np.mean([np.sum((w - mean) ** 2) for w in ws]))

    assert spread(p_up) * 10 < spread(p_ind)


def test_batched_logits_matches_single():
    cfg = tiny_cfg()
    params = model.init_params(cfg, jax.random.key(2))
    toks = jnp.array(np.random.default_rng(3).integers(0, 32, size=(3, 8)), jnp.int32)
    batched = model.batched_logits(params, cfg, toks)
    for i in range(3):
        single = model.logits_fn(params, cfg, toks[i])
        assert_allclose(np.asarray(batched[i]), np.asarray(single), rtol=1e-5, atol=1e-5)
