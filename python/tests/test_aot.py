"""AOT lowering tests: HLO text generation works for both the plain and the
Pallas-kernel paths (interpret=True lowers to plain HLO ops executable on
any PJRT backend)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile.aot import to_hlo_text
from compile.kernels import barycenter_moe as bm


def test_plain_fn_lowers_to_hlo_text():
    def fn(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    text = to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert "HloModule" in text
    assert "dot" in text


def test_pallas_kernel_lowers_to_hlo_text():
    def fn(x, hbase, u, v):
        return (bm.grouped_residual_matmul(x, hbase, u, v),)

    specs = [
        jax.ShapeDtypeStruct(s, jnp.float32)
        for s in [(4, 8), (4, 12), (2, 12, 3), (2, 3, 8)]
    ]
    text = to_hlo_text(jax.jit(fn).lower(*specs))
    assert "HloModule" in text
    # interpret=True means NO mosaic custom-call in the lowered module.
    assert "mosaic" not in text.lower()


def test_lowered_module_is_executable_by_jax_cpu():
    # Round-trip sanity: execute the jitted fn and compare with numpy.
    rng = np.random.default_rng(0)
    x = jnp.array(rng.normal(size=(4, 8)), jnp.float32)
    hbase = jnp.array(rng.normal(size=(4, 12)), jnp.float32)
    u = jnp.array(rng.normal(size=(2, 12, 3)), jnp.float32)
    v = jnp.array(rng.normal(size=(2, 3, 8)), jnp.float32)
    out = bm.grouped_residual_matmul(x, hbase, u, v)
    want = np.asarray(hbase)[None] + np.einsum(
        "bp,nrp,nir->nbi", np.asarray(x), np.asarray(v), np.asarray(u)
    )
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-4, atol=1e-4)
