"""Pallas kernels vs the pure-jnp oracle — the core L1 correctness signal.
Hypothesis sweeps shapes and values; assert_allclose against ref.py."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import barycenter_moe as bm
from compile.kernels import ref

DIMS = dict(min_value=1, max_value=24)


def rand(rng, *shape):
    return jnp.array(rng.normal(size=shape), jnp.float32)


@settings(max_examples=40, deadline=None)
@given(
    b=st.integers(**DIMS),
    p=st.integers(**DIMS),
    pi=st.integers(**DIMS),
    n=st.integers(min_value=1, max_value=8),
    r=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_grouped_residual_matmul_matches_ref(b, p, pi, n, r, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, b, p)
    hbase = rand(rng, b, pi)
    u = rand(rng, n, pi, r)
    v = rand(rng, n, r, p)
    got = bm.grouped_residual_matmul(x, hbase, u, v)
    want = ref.grouped_residual_matmul_ref(x, hbase, u, v)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(min_value=1, max_value=16),
    p=st.integers(**DIMS),
    pi=st.integers(**DIMS),
    n=st.integers(min_value=1, max_value=6),
    gated=st.booleans(),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_grouped_expert_forward_matches_ref(b, p, pi, n, gated, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, b, p)
    w1 = rand(rng, n, pi, p)
    b1 = rand(rng, n, pi)
    w2 = rand(rng, n, p, pi)
    b2 = rand(rng, n, p)
    w3 = rand(rng, n, pi, p) if gated else None
    b3 = rand(rng, n, pi) if gated else None
    got = bm.grouped_expert_forward(x, w1, b1, w2, b2, w3, b3)
    want = ref.grouped_expert_forward_ref(x, w1, b1, w2, b2, w3, b3)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("block_b", [1, 2, 4, 8])
def test_token_tiling_invariance(block_b):
    rng = np.random.default_rng(7)
    x = rand(rng, 8, 12)
    hbase = rand(rng, 8, 20)
    u = rand(rng, 3, 20, 5)
    v = rand(rng, 3, 5, 12)
    full = bm.grouped_residual_matmul(x, hbase, u, v)
    tiled = bm.grouped_residual_matmul(x, hbase, u, v, block_b=block_b)
    assert_allclose(np.asarray(full), np.asarray(tiled), rtol=1e-5, atol=1e-5)


def test_zero_residual_reduces_to_base():
    rng = np.random.default_rng(1)
    x = rand(rng, 4, 8)
    hbase = rand(rng, 4, 16)
    u = jnp.zeros((2, 16, 3), jnp.float32)
    v = jnp.zeros((2, 3, 8), jnp.float32)
    got = bm.grouped_residual_matmul(x, hbase, u, v)
    for e in range(2):
        assert_allclose(np.asarray(got[e]), np.asarray(hbase), rtol=0, atol=0)


def test_rank_additivity():
    # rank-(r1+r2) correction == rank-r1 + rank-r2 corrections.
    rng = np.random.default_rng(2)
    x = rand(rng, 5, 7)
    hbase = jnp.zeros((5, 11), jnp.float32)
    u = rand(rng, 2, 11, 6)
    v = rand(rng, 2, 6, 7)
    full = bm.grouped_residual_matmul(x, hbase, u, v)
    a = bm.grouped_residual_matmul(x, hbase, u[:, :, :3], v[:, :3, :])
    b = bm.grouped_residual_matmul(x, hbase, u[:, :, 3:], v[:, 3:, :])
    assert_allclose(np.asarray(full), np.asarray(a + b), rtol=1e-4, atol=1e-4)


def test_restored_equals_factored():
    # Algorithm-2 equivalence: computing with restored dense weights equals
    # the factored kernel path.
    rng = np.random.default_rng(3)
    b, p, pi, n, r = 6, 10, 14, 3, 4
    x = rand(rng, b, p)
    w1_base = rand(rng, pi, p)
    b1 = rand(rng, pi)
    u1 = rand(rng, n, pi, r)
    v1 = rand(rng, n, r, p)
    factored = ref.resmoe_expert_hidden_ref(x, w1_base, b1, u1, v1)
    for e in range(n):
        w1_restored = w1_base + np.asarray(u1[e]) @ np.asarray(v1[e])
        manual = np.asarray(x) @ w1_restored.T + np.asarray(b1)[None, :]
        assert_allclose(np.asarray(factored[e]), manual, rtol=1e-4, atol=1e-4)


def test_vmem_and_mxu_estimates_sane():
    resident, streamed = bm.vmem_bytes_per_step(64, 64, 224, 24)
    assert resident == 4 * (64 * 64 + 64 * 224)
    assert streamed > 0
    est = bm.mxu_utilization_estimate(64, 64, 224, 24)
    assert 0 < est["flop_ratio"] < 1  # thin factors beat dense on FLOPs
    assert est["effective_speedup"] > 1
