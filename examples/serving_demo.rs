//! Serving demo: the coordinator serving batched mixed requests over a
//! ResMoE-compressed model with a bounded restore cache (paper Alg. 2 as a
//! runtime feature). Prints throughput, latency percentiles, cache hit
//! rate, and the resident-memory story.
//!
//! ```bash
//! cargo run --release --offline --example serving_demo
//! ```

use resmoe::coordinator::{demo, ServerConfig};
use resmoe::eval::Assets;
use resmoe::moe::ModelConfig;

fn main() -> anyhow::Result<()> {
    let cfg = ModelConfig::mixtral_mini();
    let assets = Assets::load(&cfg);
    // Tight cache: ~2 restored experts per compressed layer, so the demo
    // exercises eviction under routing churn AND the steady-state memory
    // (compressed + cache) stays well below the dense expert footprint.
    let expert_bytes = cfg.params_per_expert() * 4;
    let sc = ServerConfig {
        batch_max: 8,
        batch_wait_us: 300,
        cache_budget_bytes: 2 * expert_bytes * cfg.moe_layer_indices().len(),
        workers: 2,
        ..Default::default()
    };
    demo::run_demo(&assets, sc, 64, None)
}
