//! End-to-end driver — proves all layers compose on a real small workload:
//!
//! 1. Load the JAX-pretrained mixtral-mini checkpoint (trained at `make
//!    artifacts` time on the synthetic corpus; loss curve in
//!    `artifacts/pretrain_log.json`).
//! 2. Evaluate baseline PPL + zero-shot metrics with the rust-native
//!    forward.
//! 3. Compress the top MoE layers with ResMoE(UP) and ResMoE(SVD) at 25 %,
//!    re-evaluate, and report the quality/memory trade.
//! 4. Serve batched scoring requests through BOTH engines: the rust-native
//!    cached-restore path and the PJRT path running the AOT-lowered
//!    JAX/Pallas artifacts — and cross-check their numerics.
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example end_to_end
//! ```

use resmoe::coordinator::{Engine, Request, Response, Server, ServerConfig};
use resmoe::eval::{self, tablegen, Assets};
use resmoe::moe::ModelConfig;
use resmoe::runtime::{LmScorer, Manifest, PjrtRuntime};
use resmoe::util::format_bytes;

fn main() -> anyhow::Result<()> {
    let cfg = ModelConfig::mixtral_mini();
    let assets = Assets::load(&cfg);
    println!("== 1. model ==");
    println!(
        "  {} | {} params | {}",
        cfg.name,
        assets.model.n_params(),
        if assets.pretrained {
            "pretrained checkpoint (see artifacts/pretrain_log.json for the loss curve)"
        } else {
            "RANDOM fallback — run `make artifacts` first for meaningful numbers"
        }
    );

    println!("== 2. baseline quality (rust-native forward) ==");
    let n = 150;
    let base_ppl = eval::perplexity(&assets.model, &assets.valid, cfg.max_seq);
    let base_lam = eval::lambada_accuracy(&assets.model, &assets.lambada(n)) * 100.0;
    println!("  PPL {base_ppl:.3} | LAMBADA-analog {base_lam:.2} %");

    println!("== 3. compression at 25 % ==");
    for method in ["up-concat", "resmoe-up", "resmoe-svd"] {
        let cm = tablegen::compress_with(&assets, method, 0.25, 0);
        let ppl = eval::perplexity(&cm.model, &assets.valid, cfg.max_seq);
        let lam = eval::lambada_accuracy(&cm.model, &assets.lambada(n)) * 100.0;
        println!(
            "  {:<12} PPL {ppl:7.3} | LAMBADA {lam:6.2} % | expert bytes {} -> {} | layer err {:.4}",
            method,
            format_bytes(cm.report.total_bytes_before()),
            format_bytes(cm.report.total_bytes_after()),
            cm.report.mean_approx_error(),
        );
    }

    println!("== 4. serving ==");
    let cm = tablegen::compress_with(&assets, "resmoe-up", 0.25, 0);
    let engine = Engine::compressed(assets.model.clone(), cm.layers, 8 * cfg.params_per_expert() * 4);
    let server = Server::start(engine.clone(), ServerConfig::default());
    let mut rng = resmoe::Rng::new(9);
    let seqs: Vec<Vec<u32>> = (0..32).map(|_| assets.language.generate(48, &mut rng)).collect();
    let t0 = std::time::Instant::now();
    let replies: Vec<_> = seqs
        .iter()
        .map(|s| server.submit(Request::Score { tokens: s.clone() }))
        .collect();
    let mut native_scores = Vec::new();
    for r in replies {
        match r.recv()?.0 {
            Response::Score(s) => native_scores.push(s),
            other => anyhow::bail!("unexpected {other:?}"),
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let metrics = server.shutdown();
    println!("  native cached-restore engine: {}", metrics.summary());
    println!("  ({:.1} seq/s end-to-end)", 32.0 / wall);
    if let Some(cmx) = engine.cache_metrics() {
        println!(
            "  restore cache: {:.1} % hits, {} restores, {} evictions",
            cmx.hit_rate() * 100.0,
            cmx.misses,
            cmx.evictions
        );
    }

    println!("== 5. PJRT path (AOT JAX/Pallas artifacts) ==");
    let dir = eval::harness::artifacts_dir();
    match Manifest::load(&dir) {
        Err(e) => println!("  SKIPPED: {e} — run `make artifacts`"),
        Ok(manifest) => {
            let runtime = PjrtRuntime::cpu()?;
            println!("  PJRT platform: {}", runtime.platform());
            let ckpt = dir.join(format!("{}.rmw", cfg.name));
            let scorer = LmScorer::load(&runtime, &manifest, &cfg.name, &ckpt)?;
            println!("  compiled lm_score artifacts for batches {:?}", scorer.batch_sizes());
            let t0 = std::time::Instant::now();
            let mut max_dev = 0.0f64;
            for (seq, native) in seqs.iter().take(8).zip(&native_scores) {
                let pjrt_score = scorer.mean_log_prob(seq)?;
                // The PJRT path runs the UNCOMPRESSED artifact; compare
                // against the native uncompressed model for agreement.
                let dense = Engine::dense(assets.model.clone());
                let Response::Score(native_dense) =
                    dense.handle(&Request::Score { tokens: seq.clone() })
                else {
                    unreachable!()
                };
                max_dev = max_dev.max((pjrt_score - native_dense).abs());
                let _ = native;
            }
            println!(
                "  PJRT vs rust-native logprob max deviation over 8 seqs: {max_dev:.2e} ({:.2}s)",
                t0.elapsed().as_secs_f64()
            );
            anyhow::ensure!(max_dev < 5e-3, "PJRT and native paths disagree");
            println!("  three-layer stack agreement ✓ (Pallas kernel → JAX → HLO → PJRT → rust)");
        }
    }
    Ok(())
}
