//! Quickstart: the ResMoE pipeline on one MoE layer in ~40 lines.
//!
//! ```bash
//! cargo run --release --offline --example quickstart
//! ```

use resmoe::compress::{CompressCtx, Compressor, ResMoE};
use resmoe::moe::{ExpertArch, MoeLayer};
use resmoe::tensor::Matrix;
use resmoe::util::format_bytes;
use resmoe::Rng;

fn main() {
    let mut rng = Rng::new(42);
    // A Mixtral-style MoE layer: 8 upcycled SwiGLU experts, top-2 routing.
    let layer = MoeLayer::random(ExpertArch::SwiGlu, 64, 224, 8, 2, true, false, &mut rng);
    println!(
        "original layer: 8 experts x {} params = {}",
        layer.experts[0].n_params(),
        format_bytes(layer.expert_params() * 4)
    );

    // ResMoE (Alg. 1): Wasserstein-barycenter center + pruned residuals,
    // keeping 25 % of the expert parameters.
    let mut ctx = CompressCtx::new(0.25, &mut rng);
    let compressed = ResMoE::up().compress(&layer, &mut ctx);
    println!(
        "compressed:    barycenter ({}) + residuals = {}",
        format_bytes(compressed.base.as_ref().unwrap().n_params() * 4),
        format_bytes(compressed.memory_bytes())
    );
    println!(
        "approximation error (Table-1 metric): {:.4}",
        compressed.approx_error(&layer)
    );

    // Restore (Alg. 2) and compare outputs on a token batch.
    let restored = compressed.to_layer(&layer);
    let mut xrng = Rng::new(7);
    let x = Matrix::randn(16, 64, 1.0, &mut xrng);
    let y0 = layer.forward(&x, None);
    let y1 = restored.forward(&x, None);
    let rel = y0.sq_dist(&y1) / y0.frob_norm_sq();
    println!("relative output distortion at 4x compression: {rel:.5}");

    // Versus pruning the experts directly (no barycenter).
    let mut ctx = CompressCtx::new(0.25, &mut rng);
    let plain =
        resmoe::compress::prune::UnstructuredPruning { concat: true }.compress(&layer, &mut ctx);
    println!(
        "plain UP error {:.4}  vs  ResMoE(UP) error {:.4}  — the residual trick",
        plain.approx_error(&layer),
        compressed.approx_error(&layer)
    );
}
