//! Compression-rate sweep (the Figure-4 workload as an example): evaluates
//! ResMoE against the strongest baselines across retention rates on the
//! LAMBADA analog, and prints the crossover story ("ResMoE at 10 % ≈
//! baselines at 30 %").
//!
//! ```bash
//! cargo run --release --offline --example compression_sweep [-- --fast]
//! ```

use resmoe::eval::tablegen;

fn main() {
    let fast = std::env::args().any(|a| a == "--fast") || std::env::var("RESMOE_FAST").is_ok();
    let rates: &[f64] = if fast { &[0.10, 0.30] } else { &[0.10, 0.20, 0.25, 0.30, 0.50] };
    let table = tablegen::fig4(rates);
    table.print();

    // Crossover check: ResMoE at the LOWEST rate vs baselines at the
    // highest (the paper's Figure-4 headline).
    let find = |name: &str| table.rows.iter().find(|r| r[0] == name);
    if let (Some(res), Some(up)) = (find("resmoe-up"), find("up-concat")) {
        let res_low: f64 = res[1].parse().unwrap_or(f64::NAN);
        let up_high: f64 = up.last().unwrap().parse().unwrap_or(f64::NAN);
        println!(
            "ResMoE(UP) @ {:.0}% = {res_low:.2}  vs  UP @ {:.0}% = {up_high:.2}  →  {}",
            rates[0] * 100.0,
            rates.last().unwrap() * 100.0,
            if res_low >= up_high { "ResMoE at low rate matches/beats UP at high rate ✓" } else { "no crossover at this scale" }
        );
    }
}
